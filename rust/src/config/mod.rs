//! Run configuration: defaults mirror the paper's experimental setup
//! (section 4), overridable by a TOML file and/or CLI options.
//!
//! Precedence: built-in defaults < TOML file < CLI flags.

use std::path::PathBuf;

use crate::util::error::{Context, Result};

use crate::image::Pattern;
use crate::util::cli::Cli;
use crate::util::toml::TomlDoc;

/// Everything a run needs to know.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Square image sizes to sweep. Paper: 1152…8748. Host-measured
    /// benches default to the scaled set so runs finish in seconds.
    pub sizes: Vec<usize>,
    /// Colour planes per image (paper: 3).
    pub planes: usize,
    /// Kernel width / sigma (paper: 5, σ=1 Gaussian).
    pub kernel_width: usize,
    pub sigma: f64,
    /// Timed repetitions per measurement and unrecorded warmups.
    pub reps: usize,
    pub warmup: usize,
    /// Worker threads for the execution models. The paper's magic number
    /// is 100 on 240 hw threads; on the host default to the core count.
    pub threads: usize,
    /// GPRM task cutoff (paper: 100).
    pub cutoff: usize,
    /// Tile rows for 2-D tiled dispatch (0 = full height; tiling is off
    /// when both tile dimensions are 0).
    pub tile_rows: usize,
    /// Tile columns for 2-D tiled dispatch (0 = full width).
    pub tile_cols: usize,
    /// GPRM task-agglomeration factor under tiled dispatch: tiles fused
    /// per task instance (≥ 1; the paper's Fig. 3 knob).
    pub agglomeration: usize,
    /// Fuse the two-pass pipeline into one rolling row-ring pass
    /// (two-pass requests only; single-pass algorithms ignore it). The
    /// intermediate stays in cache and plane traffic halves — the win on
    /// bandwidth-bound hardware.
    pub fuse: bool,
    /// Synthetic input pattern + seed.
    pub pattern: Pattern,
    pub seed: u64,
    /// Artifacts directory for the PJRT path.
    pub artifacts_dir: PathBuf,
    /// Coordinator admission-queue capacity: jobs waiting beyond this
    /// are shed with structured `QueueFull` errors instead of growing
    /// memory without bound.
    pub queue_capacity: usize,
    /// Default per-request deadline in milliseconds (0 = none). A
    /// request whose TTL lapses before execution is refused with a
    /// structured `DeadlineExceeded` error.
    pub deadline_ms: u64,
    /// Most jobs one executor coalesces into a single
    /// `ConvPlan::execute_batch` call when their `PlanKey`s match the
    /// head of its queue (1 = serve singly, the pre-batching behaviour).
    pub batch_max: usize,
    /// How long (µs) an executor holds a short batch open waiting for
    /// matching stragglers (0 = never wait; only meaningful with
    /// `batch_max > 1`). The wait is capped by the head job's deadline.
    pub batch_wait_us: u64,
    /// Pin each executor thread to a core (best-effort, Linux/x86-64
    /// only) so a shard's plan cache and scratch arena stay near one
    /// core's cache. Off by default: a hint, never a requirement.
    pub pin_cores: bool,
    /// Cost-model R² acceptance threshold in [0, 1]: a fitted
    /// per-(model, fused, tiled) group whose R² falls below this is
    /// never used for prediction — the planner falls back to empirical
    /// sweeping / configured defaults instead.
    pub r2_min: f64,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            sizes: crate::image::ARTIFACT_SIZES.to_vec(),
            planes: 3,
            kernel_width: 5,
            sigma: 1.0,
            reps: 20,
            warmup: 3,
            threads: default_threads(),
            cutoff: 100,
            tile_rows: 0,
            tile_cols: 0,
            agglomeration: 1,
            fuse: false,
            pattern: Pattern::Noise,
            seed: 20170710,
            artifacts_dir: crate::runtime::manifest::default_artifacts_dir(),
            queue_capacity: 256,
            deadline_ms: 0,
            batch_max: 1,
            batch_wait_us: 0,
            pin_cores: false,
            r2_min: 0.8,
        }
    }
}

/// Host parallelism (the stand-in for the Phi's 240 hw threads).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

impl RunConfig {
    /// Apply a TOML document (section `[run]`, keys match field names).
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<()> {
        if let Some(v) = doc.get("run.sizes") {
            self.sizes = v
                .as_usize_arr()
                .context("run.sizes must be an array of integers")?;
        }
        self.planes = doc.usize_or("run.planes", self.planes);
        self.kernel_width = doc.usize_or("run.kernel_width", self.kernel_width);
        self.sigma = doc.f64_or("run.sigma", self.sigma);
        self.reps = doc.usize_or("run.reps", self.reps);
        self.warmup = doc.usize_or("run.warmup", self.warmup);
        self.threads = doc.usize_or("run.threads", self.threads);
        self.cutoff = doc.usize_or("run.cutoff", self.cutoff);
        self.tile_rows = doc.usize_or("run.tile_rows", self.tile_rows);
        self.tile_cols = doc.usize_or("run.tile_cols", self.tile_cols);
        self.agglomeration = doc.usize_or("run.agglomeration", self.agglomeration);
        self.fuse = doc.bool_or("run.fuse", self.fuse);
        if let Some(p) = doc.get("run.pattern") {
            let s = p.as_str().context("run.pattern must be a string")?;
            self.pattern =
                Pattern::parse(s).with_context(|| format!("unknown pattern {s:?}"))?;
        }
        self.seed = doc.usize_or("run.seed", self.seed as usize) as u64;
        if let Some(d) = doc.get("run.artifacts_dir") {
            self.artifacts_dir = PathBuf::from(d.as_str().context("artifacts_dir")?);
        }
        self.queue_capacity = doc.usize_or("run.queue_capacity", self.queue_capacity);
        // parsed strictly (not through the usize helper): deadline_ms
        // is u64 and must not truncate on 32-bit targets, and a
        // negative or fractional TTL must be an error — a silent `as`
        // coercion to 0 would disable the deadline the operator set
        if let Some(v) = doc.get("run.deadline_ms") {
            let n = v.as_f64().context("run.deadline_ms must be a number")?;
            ensure!(
                n >= 0.0 && n.fract() == 0.0,
                "run.deadline_ms must be a non-negative integer, got {n}"
            );
            self.deadline_ms = n as u64;
        }
        self.batch_max = doc.usize_or("run.batch_max", self.batch_max);
        // strict for the same reason as deadline_ms: u64, and a negative
        // or fractional wait must error rather than coerce to 0
        if let Some(v) = doc.get("run.batch_wait_us") {
            let n = v.as_f64().context("run.batch_wait_us must be a number")?;
            ensure!(
                n >= 0.0 && n.fract() == 0.0,
                "run.batch_wait_us must be a non-negative integer, got {n}"
            );
            self.batch_wait_us = n as u64;
        }
        self.pin_cores = doc.bool_or("run.pin_cores", self.pin_cores);
        self.r2_min = doc.f64_or("run.r2_min", self.r2_min);
        Ok(())
    }

    /// Apply CLI overrides (flags are declared by `standard_cli`).
    pub fn apply_cli(&mut self, cli: &Cli) -> Result<()> {
        if let Some(s) = cli.get("sizes") {
            if !s.is_empty() {
                self.sizes = cli.usize_list_of("sizes")?;
            }
        }
        fn set(cli: &Cli, key: &str, field: &mut usize) -> Result<()> {
            if let Some(v) = cli.get(key) {
                if !v.is_empty() {
                    *field = v.parse()?;
                }
            }
            Ok(())
        }
        set(cli, "planes", &mut self.planes)?;
        set(cli, "kernel-width", &mut self.kernel_width)?;
        set(cli, "reps", &mut self.reps)?;
        set(cli, "warmup", &mut self.warmup)?;
        set(cli, "threads", &mut self.threads)?;
        set(cli, "cutoff", &mut self.cutoff)?;
        set(cli, "tile-rows", &mut self.tile_rows)?;
        set(cli, "tile-cols", &mut self.tile_cols)?;
        set(cli, "agglomeration", &mut self.agglomeration)?;
        set(cli, "queue-capacity", &mut self.queue_capacity)?;
        set(cli, "batch-max", &mut self.batch_max)?;
        if cli.is_set("fuse") {
            self.fuse = true; // a flag can only turn fusion on (TOML can set either)
        }
        if cli.is_set("pin-cores") {
            self.pin_cores = true; // flag turns pinning on (TOML can set either)
        }
        if let Some(v) = cli.get("deadline-ms") {
            if !v.is_empty() {
                self.deadline_ms = v.parse()?;
            }
        }
        if let Some(v) = cli.get("batch-wait-us") {
            if !v.is_empty() {
                self.batch_wait_us = v.parse()?;
            }
        }
        if let Some(s) = cli.get("sigma") {
            if !s.is_empty() {
                self.sigma = s.parse()?;
            }
        }
        if let Some(s) = cli.get("r2-min") {
            if !s.is_empty() {
                self.r2_min = s.parse()?;
            }
        }
        if let Some(p) = cli.get("pattern") {
            if !p.is_empty() {
                self.pattern =
                    Pattern::parse(p).with_context(|| format!("unknown pattern {p:?}"))?;
            }
        }
        if let Some(s) = cli.get("seed") {
            if !s.is_empty() {
                self.seed = s.parse()?;
            }
        }
        if let Some(d) = cli.get("artifacts") {
            if !d.is_empty() {
                self.artifacts_dir = PathBuf::from(d);
            }
        }
        Ok(())
    }

    /// The run's kernel as a plan-layer spec.
    pub fn kernel_spec(&self) -> crate::plan::KernelSpec {
        crate::plan::KernelSpec::new(self.kernel_width, self.sigma)
    }

    /// The run's tile decomposition: `None` when both tile dimensions
    /// are 0 (untiled row-band dispatch); a 0 in one dimension means
    /// "full extent" (clamped at grid resolution).
    pub fn tile_spec(&self) -> Option<crate::plan::TileSpec> {
        let full = |d: usize| if d == 0 { usize::MAX } else { d };
        match (self.tile_rows, self.tile_cols) {
            (0, 0) => None,
            (r, c) => Some(crate::plan::TileSpec::new(full(r), full(c))),
        }
    }

    /// Structured validation of the resolved configuration — the CLI
    /// entry point for kernel errors (no silent fallback downstream).
    pub fn validate(&self) -> Result<()> {
        self.kernel_spec().validate()?;
        ensure!(self.planes >= 1, "planes must be >= 1");
        ensure!(!self.sizes.is_empty(), "sizes must be non-empty");
        ensure!(self.sizes.iter().all(|&s| s >= 1), "every size must be >= 1, got {:?}", self.sizes);
        ensure!(self.queue_capacity >= 1, "queue_capacity must be >= 1");
        ensure!(self.agglomeration >= 1, "agglomeration must be >= 1");
        ensure!(self.batch_max >= 1, "batch_max must be >= 1");
        ensure!(
            (0.0..=1.0).contains(&self.r2_min),
            "r2_min must be in [0, 1], got {}",
            self.r2_min
        );
        Ok(())
    }

    /// Bench-binary configuration from the `PHI_BENCH_*` env knobs
    /// shared by every bench target (previously copy-pasted into each):
    /// `PHI_BENCH_SIZES` (default `288,576` to keep default bench runtime
    /// bounded), `PHI_BENCH_REPS` (default 5), `PHI_BENCH_WARMUP`
    /// (default 2), `PHI_BENCH_THREADS` (default: host cores). Panics on
    /// malformed values — benches are developer-facing binaries.
    pub fn from_bench_env() -> Self {
        let mut cfg = Self::default();
        if let Ok(s) = std::env::var("PHI_BENCH_SIZES") {
            cfg.sizes = s.split(',').map(|x| x.trim().parse().expect("size")).collect();
        } else {
            cfg.sizes = vec![288, 576];
        }
        cfg.reps = std::env::var("PHI_BENCH_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
        // same parse rule as ExecutionModel::overhead_probe, so benches
        // and probes agree on what PHI_BENCH_WARMUP means
        cfg.warmup = crate::models::overhead_warmup();
        if let Ok(t) = std::env::var("PHI_BENCH_THREADS") {
            cfg.threads = t.parse().expect("threads");
        }
        cfg.validate().expect("PHI_BENCH_* configuration");
        cfg
    }

    /// Resolve from optional TOML path + CLI.
    pub fn resolve(cli: &Cli) -> Result<Self> {
        let mut cfg = Self::default();
        if let Some(path) = cli.get("config") {
            if !path.is_empty() {
                let text = std::fs::read_to_string(path)
                    .with_context(|| format!("reading config {path}"))?;
                cfg.apply_toml(&TomlDoc::parse(&text)?)?;
            }
        }
        cfg.apply_cli(cli)?;
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Declare the standard option set shared by the CLI binary and examples.
pub fn standard_cli(bin: &'static str, about: &'static str) -> Cli {
    Cli::new(bin, about)
        .opt("config", "", "TOML config file (section [run])")
        .opt("sizes", "", "comma-separated square sizes (default 288,576,1152)")
        .opt("planes", "", "colour planes (default 3)")
        .opt("kernel-width", "", "odd Gaussian kernel width (default 5)")
        .opt("sigma", "", "Gaussian sigma (default 1.0)")
        .opt("reps", "", "timed repetitions (default 20)")
        .opt("warmup", "", "warmup runs (default 3)")
        .opt("threads", "", "worker threads (default: host cores)")
        .opt("cutoff", "", "GPRM task cutoff (default 100)")
        .opt("tile-rows", "", "tile rows for 2-D dispatch (0 = full height; default 0)")
        .opt("tile-cols", "", "tile columns for 2-D dispatch (0 = full width; default 0)")
        .opt("agglomeration", "", "GPRM tiles fused per task under tiling (default 1)")
        .flag("fuse", "fuse the two-pass pipeline (rolling row-ring; halves plane traffic)")
        .opt("pattern", "", "input pattern: noise|ramp-x|ramp-xy|checker|disc|constant")
        .opt("seed", "", "PRNG seed (default 20170710)")
        .opt("artifacts", "", "artifacts directory (default ./artifacts)")
        .opt("queue-capacity", "", "coordinator admission-queue capacity (default 256)")
        .opt("deadline-ms", "", "per-request deadline in ms, 0 = none (default 0)")
        .opt("batch-max", "", "max jobs coalesced per plan-keyed batch (default 1 = serve singly)")
        .opt("batch-wait-us", "", "straggler wait in microseconds before closing a short batch (default 0)")
        .flag("pin-cores", "pin executor threads to cores (best-effort, Linux/x86-64)")
        .opt("r2-min", "", "cost-model R² acceptance threshold in [0,1] (default 0.8)")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = RunConfig::default();
        assert_eq!(c.planes, 3);
        assert_eq!(c.kernel_width, 5);
        assert_eq!(c.cutoff, 100);
        assert_eq!(c.sizes, vec![288, 576, 1152]);
    }

    #[test]
    fn toml_overrides() {
        let mut c = RunConfig::default();
        let doc = TomlDoc::parse(
            "[run]\nsizes = [64, 128]\nthreads = 8\npattern = \"checker\"\nsigma = 2.0\n",
        )
        .unwrap();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.sizes, vec![64, 128]);
        assert_eq!(c.threads, 8);
        assert_eq!(c.pattern, Pattern::Checker);
        assert!((c.sigma - 2.0).abs() < 1e-12);
        // untouched fields keep defaults
        assert_eq!(c.cutoff, 100);
    }

    #[test]
    fn cli_overrides_beat_toml() {
        let mut c = RunConfig::default();
        let doc = TomlDoc::parse("[run]\nthreads = 8\n").unwrap();
        c.apply_toml(&doc).unwrap();
        let cli = standard_cli("t", "t")
            .parse(["--threads".to_string(), "4".to_string()])
            .unwrap();
        c.apply_cli(&cli).unwrap();
        assert_eq!(c.threads, 4);
    }

    #[test]
    fn bad_pattern_rejected() {
        let mut c = RunConfig::default();
        let doc = TomlDoc::parse("[run]\npattern = \"bogus\"\n").unwrap();
        assert!(c.apply_toml(&doc).is_err());
    }

    #[test]
    fn kernel_flags_plumb_through_cli() {
        let cli = standard_cli("t", "t")
            .parse(["--kernel-width".to_string(), "7".to_string(), "--sigma".to_string(), "2.5".to_string()])
            .unwrap();
        let c = RunConfig::resolve(&cli).unwrap();
        assert_eq!(c.kernel_width, 7);
        assert!((c.sigma - 2.5).abs() < 1e-12);
        assert_eq!(c.kernel_spec(), crate::plan::KernelSpec::new(7, 2.5));
    }

    #[test]
    fn queue_knobs_plumb_through_cli_and_toml() {
        let c = RunConfig::default();
        assert_eq!(c.queue_capacity, 256);
        assert_eq!(c.deadline_ms, 0);

        let mut c = RunConfig::default();
        let doc = TomlDoc::parse("[run]\nqueue_capacity = 32\ndeadline_ms = 750\n").unwrap();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.queue_capacity, 32);
        assert_eq!(c.deadline_ms, 750);

        let cli = standard_cli("t", "t")
            .parse([
                "--queue-capacity".to_string(),
                "8".to_string(),
                "--deadline-ms".to_string(),
                "100".to_string(),
            ])
            .unwrap();
        let c = RunConfig::resolve(&cli).unwrap();
        assert_eq!(c.queue_capacity, 8);
        assert_eq!(c.deadline_ms, 100);
    }

    #[test]
    fn negative_or_fractional_toml_deadline_rejected() {
        // the CLI path rejects these via u64 parse; the TOML path must
        // not silently coerce them to 0 (= "no deadline")
        for bad in ["deadline_ms = -250", "deadline_ms = 0.5"] {
            let mut c = RunConfig::default();
            let doc = TomlDoc::parse(&format!("[run]\n{bad}\n")).unwrap();
            assert!(c.apply_toml(&doc).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn tiling_knobs_plumb_through_cli_and_toml() {
        let c = RunConfig::default();
        assert_eq!((c.tile_rows, c.tile_cols, c.agglomeration), (0, 0, 1));
        assert_eq!(c.tile_spec(), None, "untiled by default");

        let mut c = RunConfig::default();
        let doc =
            TomlDoc::parse("[run]\ntile_rows = 16\ntile_cols = 64\nagglomeration = 4\n").unwrap();
        c.apply_toml(&doc).unwrap();
        assert_eq!((c.tile_rows, c.tile_cols, c.agglomeration), (16, 64, 4));
        assert_eq!(c.tile_spec(), Some(crate::plan::TileSpec::new(16, 64)));

        let cli = standard_cli("t", "t")
            .parse([
                "--tile-rows".to_string(),
                "8".to_string(),
                "--agglomeration".to_string(),
                "2".to_string(),
            ])
            .unwrap();
        let c = RunConfig::resolve(&cli).unwrap();
        assert_eq!((c.tile_rows, c.tile_cols, c.agglomeration), (8, 0, 2));
        // one zero dimension means "full extent", not "untiled"
        assert_eq!(c.tile_spec(), Some(crate::plan::TileSpec::new(8, usize::MAX)));
    }

    #[test]
    fn fuse_knob_plumbs_through_cli_and_toml() {
        assert!(!RunConfig::default().fuse, "unfused by default");

        let mut c = RunConfig::default();
        let doc = TomlDoc::parse("[run]\nfuse = true\n").unwrap();
        c.apply_toml(&doc).unwrap();
        assert!(c.fuse);
        // TOML can also switch it back off
        let doc = TomlDoc::parse("[run]\nfuse = false\n").unwrap();
        c.apply_toml(&doc).unwrap();
        assert!(!c.fuse);

        let cli = standard_cli("t", "t").parse(["--fuse".to_string()]).unwrap();
        let c = RunConfig::resolve(&cli).unwrap();
        assert!(c.fuse);
        // absent flag leaves a TOML-set value alone
        let mut c = RunConfig { fuse: true, ..Default::default() };
        let cli = standard_cli("t", "t").parse(Vec::<String>::new()).unwrap();
        c.apply_cli(&cli).unwrap();
        assert!(c.fuse);
    }

    #[test]
    fn zero_agglomeration_is_structured_error() {
        let cli = standard_cli("t", "t")
            .parse(["--agglomeration".to_string(), "0".to_string()])
            .unwrap();
        let e = RunConfig::resolve(&cli).unwrap_err();
        assert!(format!("{e:#}").contains("agglomeration"), "got: {e:#}");
    }

    #[test]
    fn batching_knobs_plumb_through_cli_and_toml() {
        let c = RunConfig::default();
        assert_eq!(c.batch_max, 1, "serve singly by default");
        assert_eq!(c.batch_wait_us, 0);
        assert!(!c.pin_cores);

        let mut c = RunConfig::default();
        let doc = TomlDoc::parse(
            "[run]\nbatch_max = 8\nbatch_wait_us = 150\npin_cores = true\n",
        )
        .unwrap();
        c.apply_toml(&doc).unwrap();
        assert_eq!((c.batch_max, c.batch_wait_us, c.pin_cores), (8, 150, true));
        // TOML can switch pinning back off
        let doc = TomlDoc::parse("[run]\npin_cores = false\n").unwrap();
        c.apply_toml(&doc).unwrap();
        assert!(!c.pin_cores);

        let cli = standard_cli("t", "t")
            .parse([
                "--batch-max".to_string(),
                "4".to_string(),
                "--batch-wait-us".to_string(),
                "50".to_string(),
                "--pin-cores".to_string(),
            ])
            .unwrap();
        let c = RunConfig::resolve(&cli).unwrap();
        assert_eq!((c.batch_max, c.batch_wait_us, c.pin_cores), (4, 50, true));
        // absent flag leaves a TOML-set value alone
        let mut c = RunConfig { pin_cores: true, ..Default::default() };
        let cli = standard_cli("t", "t").parse(Vec::<String>::new()).unwrap();
        c.apply_cli(&cli).unwrap();
        assert!(c.pin_cores);
    }

    #[test]
    fn zero_batch_max_is_structured_error() {
        let cli = standard_cli("t", "t")
            .parse(["--batch-max".to_string(), "0".to_string()])
            .unwrap();
        let e = RunConfig::resolve(&cli).unwrap_err();
        assert!(format!("{e:#}").contains("batch_max"), "got: {e:#}");
    }

    #[test]
    fn negative_or_fractional_toml_batch_wait_rejected() {
        for bad in ["batch_wait_us = -10", "batch_wait_us = 1.5"] {
            let mut c = RunConfig::default();
            let doc = TomlDoc::parse(&format!("[run]\n{bad}\n")).unwrap();
            assert!(c.apply_toml(&doc).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn r2_min_plumbs_through_cli_and_toml() {
        assert!((RunConfig::default().r2_min - 0.8).abs() < 1e-12, "default gate is 0.8");

        let mut c = RunConfig::default();
        let doc = TomlDoc::parse("[run]\nr2_min = 0.95\n").unwrap();
        c.apply_toml(&doc).unwrap();
        assert!((c.r2_min - 0.95).abs() < 1e-12);

        let cli = standard_cli("t", "t")
            .parse(["--r2-min".to_string(), "0.5".to_string()])
            .unwrap();
        let c = RunConfig::resolve(&cli).unwrap();
        assert!((c.r2_min - 0.5).abs() < 1e-12);
    }

    #[test]
    fn out_of_range_r2_min_is_structured_error() {
        for bad in ["1.5", "-0.1"] {
            let cli = standard_cli("t", "t")
                .parse(["--r2-min".to_string(), bad.to_string()])
                .unwrap();
            let e = RunConfig::resolve(&cli).unwrap_err();
            assert!(format!("{e:#}").contains("r2_min"), "{bad}: got {e:#}");
        }
    }

    #[test]
    fn zero_queue_capacity_is_structured_error() {
        let cli = standard_cli("t", "t")
            .parse(["--queue-capacity".to_string(), "0".to_string()])
            .unwrap();
        let e = RunConfig::resolve(&cli).unwrap_err();
        assert!(format!("{e:#}").contains("queue_capacity"), "got: {e:#}");
    }

    #[test]
    fn even_kernel_width_is_structured_cli_error() {
        let cli = standard_cli("t", "t")
            .parse(["--kernel-width".to_string(), "4".to_string()])
            .unwrap();
        let e = RunConfig::resolve(&cli).unwrap_err();
        assert!(format!("{e:#}").contains("odd"), "got: {e:#}");
        assert_eq!(
            e.kind(),
            crate::util::error::ErrorKind::InvalidKernel,
            "kernel refusals carry their structured kind through the CLI entry point"
        );
    }
}
