//! Minimal binary PGM (P5) / PPM (P6) I/O.
//!
//! Enough to exchange images with standard tools for eyeballing results;
//! 8-bit depth, f32 pixels clamped/scaled to [0, 255].

use std::io::{Read, Write};
use std::path::Path;

use crate::util::error::{Context, Result};

use super::planar::PlanarImage;

fn scale_to_u8(v: f32, lo: f32, hi: f32) -> u8 {
    if hi <= lo {
        return 0;
    }
    (((v - lo) / (hi - lo)).clamp(0.0, 1.0) * 255.0).round() as u8
}

fn min_max(data: &[f32]) -> (f32, f32) {
    data.iter().fold((f32::MAX, f32::MIN), |(lo, hi), &v| (lo.min(v), hi.max(v)))
}

/// Write one plane as binary PGM, auto-scaling to 8-bit.
pub fn write_pgm(path: impl AsRef<Path>, img: &PlanarImage, plane: usize) -> Result<()> {
    let data = img.plane(plane);
    let (lo, hi) = min_max(data);
    let mut f = std::fs::File::create(path)?;
    write!(f, "P5\n{} {}\n255\n", img.cols, img.rows)?;
    let bytes: Vec<u8> = data.iter().map(|&v| scale_to_u8(v, lo, hi)).collect();
    f.write_all(&bytes)?;
    Ok(())
}

/// Write a 3-plane image as binary PPM (plane 0→R, 1→G, 2→B), auto-scaled.
pub fn write_ppm(path: impl AsRef<Path>, img: &PlanarImage) -> Result<()> {
    if img.planes < 3 {
        bail!("PPM needs 3 planes, image has {}", img.planes);
    }
    let (lo, hi) = min_max(&img.data);
    let mut f = std::fs::File::create(path)?;
    write!(f, "P6\n{} {}\n255\n", img.cols, img.rows)?;
    let mut bytes = Vec::with_capacity(img.rows * img.cols * 3);
    for i in 0..img.rows {
        for j in 0..img.cols {
            for p in 0..3 {
                bytes.push(scale_to_u8(img.get(p, i, j), lo, hi));
            }
        }
    }
    f.write_all(&bytes)?;
    Ok(())
}

/// Read a binary PGM (P5) into a 1-plane image with pixels in [0, 1].
pub fn read_pgm(path: impl AsRef<Path>) -> Result<PlanarImage> {
    let mut raw = Vec::new();
    std::fs::File::open(&path)
        .with_context(|| format!("open {}", path.as_ref().display()))?
        .read_to_end(&mut raw)?;
    let mut pos = 0usize;

    let mut token = |raw: &[u8]| -> Result<String> {
        // skip whitespace and `#` comment lines
        loop {
            while pos < raw.len() && raw[pos].is_ascii_whitespace() {
                pos += 1;
            }
            if pos < raw.len() && raw[pos] == b'#' {
                while pos < raw.len() && raw[pos] != b'\n' {
                    pos += 1;
                }
                continue;
            }
            break;
        }
        let start = pos;
        while pos < raw.len() && !raw[pos].is_ascii_whitespace() {
            pos += 1;
        }
        if start == pos {
            bail!("truncated PGM header");
        }
        Ok(std::str::from_utf8(&raw[start..pos])?.to_string())
    };

    let magic = token(&raw)?;
    if magic != "P5" {
        bail!("unsupported magic {magic:?} (only binary PGM P5)");
    }
    let cols: usize = token(&raw)?.parse()?;
    let rows: usize = token(&raw)?.parse()?;
    let maxval: usize = token(&raw)?.parse()?;
    if maxval == 0 || maxval > 255 {
        bail!("unsupported maxval {maxval}");
    }
    pos += 1; // single whitespace after maxval
    if raw.len() < pos + rows * cols {
        bail!("PGM pixel data truncated: want {} bytes", rows * cols);
    }
    let data: Vec<f32> = raw[pos..pos + rows * cols]
        .iter()
        .map(|&b| b as f32 / maxval as f32)
        .collect();
    PlanarImage::from_vec(1, rows, cols, data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth::{synth_image, Pattern};

    #[test]
    fn pgm_roundtrip() {
        let img = synth_image(1, 24, 32, Pattern::Disc, 0);
        let dir = std::env::temp_dir().join("phi_conv_pgm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("disc.pgm");
        write_pgm(&path, &img, 0).unwrap();
        let back = read_pgm(&path).unwrap();
        assert_eq!(back.rows, 24);
        assert_eq!(back.cols, 32);
        // disc is 0/1-valued: survives 8-bit quantisation exactly
        for (a, b) in img.data.iter().zip(&back.data) {
            assert!((a - b).abs() < 1.0 / 255.0 + 1e-6);
        }
    }

    #[test]
    fn ppm_writes(/* smoke: header + size */) {
        let img = synth_image(3, 8, 9, Pattern::Noise, 3);
        let dir = std::env::temp_dir().join("phi_conv_pgm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rgb.ppm");
        write_ppm(&path, &img).unwrap();
        let raw = std::fs::read(&path).unwrap();
        assert!(raw.starts_with(b"P6\n9 8\n255\n"));
        assert_eq!(raw.len(), "P6\n9 8\n255\n".len() + 8 * 9 * 3);
    }

    #[test]
    fn ppm_needs_three_planes() {
        let img = synth_image(1, 8, 8, Pattern::Noise, 0);
        let path = std::env::temp_dir().join("phi_conv_nope.ppm");
        assert!(write_ppm(path, &img).is_err());
    }

    #[test]
    fn pgm_rejects_bad_magic() {
        let dir = std::env::temp_dir().join("phi_conv_pgm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.pgm");
        std::fs::write(&path, b"P2\n2 2\n255\n0 0 0 0").unwrap();
        assert!(read_pgm(&path).is_err());
    }

    fn write_case(name: &str, bytes: &[u8]) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("phi_conv_pgm_neg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        std::fs::write(&path, bytes).unwrap();
        path
    }

    #[test]
    fn pgm_rejects_truncated_header() {
        // header ends after the magic: no dims, no maxval
        let e = read_pgm(write_case("trunc_header.pgm", b"P5\n")).unwrap_err();
        assert!(e.to_string().contains("truncated PGM header"), "{e}");
        // dims present but maxval missing
        let e = read_pgm(write_case("no_maxval.pgm", b"P5\n2 2\n")).unwrap_err();
        assert!(e.to_string().contains("truncated PGM header"), "{e}");
    }

    #[test]
    fn pgm_rejects_empty_file() {
        assert!(read_pgm(write_case("empty.pgm", b"")).is_err());
    }

    #[test]
    fn pgm_rejects_maxval_zero() {
        let mut bytes = b"P5\n2 2\n0\n".to_vec();
        bytes.extend_from_slice(&[0, 0, 0, 0]);
        let e = read_pgm(write_case("maxval0.pgm", &bytes)).unwrap_err();
        assert!(e.to_string().contains("unsupported maxval"), "{e}");
    }

    #[test]
    fn pgm_rejects_wide_maxval() {
        // 16-bit PGM (maxval > 255) is out of scope for this 8-bit reader
        let mut bytes = b"P5\n2 2\n65535\n".to_vec();
        bytes.extend_from_slice(&[0; 8]);
        let e = read_pgm(write_case("maxval16.pgm", &bytes)).unwrap_err();
        assert!(e.to_string().contains("unsupported maxval"), "{e}");
    }

    #[test]
    fn pgm_rejects_truncated_pixel_data() {
        let mut bytes = b"P5\n4 4\n255\n".to_vec();
        bytes.extend_from_slice(&[7; 15]); // one byte short of 16
        let e = read_pgm(write_case("trunc_pixels.pgm", &bytes)).unwrap_err();
        assert!(e.to_string().contains("truncated"), "{e}");
    }

    #[test]
    fn pgm_rejects_non_numeric_dims() {
        let e = read_pgm(write_case("bad_dims.pgm", b"P5\nxx 2\n255\n\0\0\0\0")).unwrap_err();
        assert!(e.to_string().contains("invalid digit"), "{e}");
    }

    #[test]
    fn pgm_rejects_missing_file() {
        let e = read_pgm("/nonexistent/phi_conv.pgm").unwrap_err();
        assert!(e.to_string().contains("open"), "{e}");
    }

    #[test]
    fn pgm_roundtrip_per_plane_of_rgb() {
        // write each plane of a 3-plane image, read back, compare scaled
        let img = synth_image(3, 12, 16, Pattern::Checker, 2);
        let dir = std::env::temp_dir().join("phi_conv_pgm_test");
        std::fs::create_dir_all(&dir).unwrap();
        for p in 0..3 {
            let path = dir.join(format!("plane{p}.pgm"));
            write_pgm(&path, &img, p).unwrap();
            let back = read_pgm(&path).unwrap();
            assert_eq!((back.rows, back.cols, back.planes), (12, 16, 1));
            // checker is 0/1-valued: exact after quantisation
            for (a, b) in img.plane(p).iter().zip(&back.data) {
                assert!((a - b).abs() < 1.0 / 255.0 + 1e-6, "plane {p}");
            }
        }
    }

    #[test]
    fn pgm_handles_comments() {
        let dir = std::env::temp_dir().join("phi_conv_pgm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("comment.pgm");
        let mut bytes = b"P5\n# a comment\n2 2\n255\n".to_vec();
        bytes.extend_from_slice(&[0, 128, 255, 64]);
        std::fs::write(&path, &bytes).unwrap();
        let img = read_pgm(&path).unwrap();
        assert_eq!(img.rows, 2);
        assert!((img.get(0, 0, 1) - 128.0 / 255.0).abs() < 1e-6);
    }
}
