//! Separable Gaussian kernel construction.
//!
//! Must match `python/compile/kernels/ref.py::gaussian_kernel` bit-for-bit
//! after the f64→f32 cast: the manifest ships the Python values and the
//! tests cross-check (`runtime::manifest` carries `kernel_values`).

/// The paper's kernel width.
pub const KERNEL_WIDTH: usize = 5;

/// Normalised 1-D Gaussian vector of odd `width` (computed in f64, cast
/// to f32 at the end, same as the Python reference).
pub fn gaussian_kernel(width: usize, sigma: f64) -> Vec<f32> {
    assert!(width % 2 == 1, "kernel width must be odd, got {width}");
    let h = (width / 2) as i64;
    let mut k: Vec<f64> = (-h..=h)
        .map(|x| (-((x * x) as f64) / (2.0 * sigma * sigma)).exp())
        .collect();
    let s: f64 = k.iter().sum();
    for v in &mut k {
        *v /= s;
    }
    k.into_iter().map(|v| v as f32).collect()
}

/// K[i][j] = k[i]·k[j]: the 2-D kernel of a separable vector, row-major.
pub fn gaussian_kernel2d(k: &[f32]) -> Vec<f32> {
    let w = k.len();
    let mut kk = vec![0f32; w * w];
    for i in 0..w {
        for j in 0..w {
            kk[i * w + j] = k[i] * k[j];
        }
    }
    kk
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalised_and_symmetric() {
        for width in [3usize, 5, 7, 9] {
            let k = gaussian_kernel(width, 1.0);
            let s: f32 = k.iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "width {width}: sum {s}");
            for i in 0..width {
                assert_eq!(k[i], k[width - 1 - i], "width {width} not symmetric");
            }
            // peak at centre
            let mx = k.iter().cloned().fold(f32::MIN, f32::max);
            assert_eq!(k[width / 2], mx);
        }
    }

    #[test]
    #[should_panic(expected = "odd")]
    fn rejects_even_width() {
        gaussian_kernel(4, 1.0);
    }

    #[test]
    fn known_values_width5_sigma1() {
        // Same constants the Python oracle produces (f64 math, f32 cast).
        let k = gaussian_kernel(5, 1.0);
        let want = [0.05448868, 0.24420135, 0.40261996, 0.24420135, 0.05448868];
        for (g, w) in k.iter().zip(want) {
            assert!((g - w).abs() < 1e-6, "{g} vs {w}");
        }
    }

    #[test]
    fn kernel2d_is_outer_product() {
        let k = gaussian_kernel(5, 1.0);
        let kk = gaussian_kernel2d(&k);
        for i in 0..5 {
            for j in 0..5 {
                assert_eq!(kk[i * 5 + j], k[i] * k[j]);
            }
        }
        let s: f32 = kk.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }

    #[test]
    fn wider_sigma_flatter_kernel() {
        let narrow = gaussian_kernel(5, 0.5);
        let wide = gaussian_kernel(5, 3.0);
        assert!(narrow[2] > wide[2]);
        assert!(narrow[0] < wide[0]);
    }
}
