//! `PlanarImage`: the paper's `float ***A` — P planes of R×C f32 pixels —
//! as one contiguous buffer with plane views.

use crate::util::error::Result;

/// A planar (plane-major) f32 image: `data[p*R*C + i*C + j]`.
///
/// Contiguous storage keeps the PJRT handoff zero-copy-shaped (the
/// artifacts take `(P, R, C)` tensors in exactly this layout) and makes
/// the agglomerated 3R×C view a cheap re-indexing.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanarImage {
    pub planes: usize,
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl PlanarImage {
    /// Zero-filled image.
    pub fn zeros(planes: usize, rows: usize, cols: usize) -> Self {
        Self { planes, rows, cols, data: vec![0.0; planes * rows * cols] }
    }

    /// Wrap an existing buffer (must match `planes*rows*cols`).
    pub fn from_vec(planes: usize, rows: usize, cols: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != planes * rows * cols {
            bail!(
                "buffer has {} elements, {}x{}x{} needs {}",
                data.len(),
                planes,
                rows,
                cols,
                planes * rows * cols
            );
        }
        Ok(Self { planes, rows, cols, data })
    }

    pub fn plane_len(&self) -> usize {
        self.rows * self.cols
    }

    /// Immutable view of one plane.
    pub fn plane(&self, p: usize) -> &[f32] {
        let n = self.plane_len();
        &self.data[p * n..(p + 1) * n]
    }

    /// Mutable view of one plane.
    pub fn plane_mut(&mut self, p: usize) -> &mut [f32] {
        let n = self.plane_len();
        &mut self.data[p * n..(p + 1) * n]
    }

    pub fn get(&self, p: usize, i: usize, j: usize) -> f32 {
        self.data[p * self.plane_len() + i * self.cols + j]
    }

    pub fn set(&mut self, p: usize, i: usize, j: usize, v: f32) {
        let n = self.plane_len();
        self.data[p * n + i * self.cols + j] = v;
    }

    /// The paper's 3R×C task-agglomeration layout: planes concatenated
    /// along columns, `wide[i][p*C + j] = img[p][i][j]`.
    pub fn agglomerate(&self) -> Vec<f32> {
        let (p_, r, c) = (self.planes, self.rows, self.cols);
        let wc = p_ * c;
        let mut wide = vec![0f32; r * wc];
        for p in 0..p_ {
            let plane = self.plane(p);
            for i in 0..r {
                wide[i * wc + p * c..i * wc + p * c + c]
                    .copy_from_slice(&plane[i * c..(i + 1) * c]);
            }
        }
        wide
    }

    /// Inverse of [`agglomerate`]: scatter a (R, P·C) buffer back to planes.
    pub fn from_agglomerated(planes: usize, rows: usize, cols: usize, wide: &[f32]) -> Result<Self> {
        if wide.len() != planes * rows * cols {
            bail!("agglomerated buffer wrong size");
        }
        let wc = planes * cols;
        let mut img = Self::zeros(planes, rows, cols);
        for p in 0..planes {
            for i in 0..rows {
                let src = &wide[i * wc + p * cols..i * wc + p * cols + cols];
                let n = img.plane_len();
                img.data[p * n + i * cols..p * n + (i + 1) * cols].copy_from_slice(src);
            }
        }
        Ok(img)
    }

    /// Max |a−b| over all pixels (for oracle comparisons).
    pub fn max_abs_diff(&self, other: &Self) -> f32 {
        assert_eq!(self.data.len(), other.data.len());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Max |a−b| restricted to the deep interior `[d, n-d)` of every
    /// plane, where single-pass and two-pass provably agree (d = 2h).
    ///
    /// Planes too small to have a deep interior (`rows ≤ 2d` or
    /// `cols ≤ 2d` — reachable since arbitrary odd kernel widths meet
    /// tiny planes) compare as 0.0: there are no interior pixels to
    /// disagree on. The old `d..rows - d` range underflowed and
    /// panicked on such shapes.
    pub fn max_abs_diff_deep(&self, other: &Self, halo: usize) -> f32 {
        let d = 2 * halo;
        if self.rows <= 2 * d || self.cols <= 2 * d {
            return 0.0;
        }
        let mut m = 0f32;
        for p in 0..self.planes {
            let (a, b) = (self.plane(p), other.plane(p));
            for i in d..self.rows - d {
                for j in d..self.cols - d {
                    m = m.max((a[i * self.cols + j] - b[i * self.cols + j]).abs());
                }
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_and_views() {
        let mut img = PlanarImage::zeros(3, 4, 5);
        img.set(2, 3, 4, 7.5);
        assert_eq!(img.get(2, 3, 4), 7.5);
        assert_eq!(img.plane(2)[3 * 5 + 4], 7.5);
        assert_eq!(img.data[2 * 20 + 3 * 5 + 4], 7.5);
        img.plane_mut(0)[0] = 1.0;
        assert_eq!(img.get(0, 0, 0), 1.0);
    }

    #[test]
    fn from_vec_validates() {
        assert!(PlanarImage::from_vec(1, 2, 2, vec![0.0; 3]).is_err());
        assert!(PlanarImage::from_vec(1, 2, 2, vec![0.0; 4]).is_ok());
    }

    #[test]
    fn agglomerate_roundtrip() {
        let mut img = PlanarImage::zeros(3, 4, 5);
        for (i, v) in img.data.iter_mut().enumerate() {
            *v = i as f32;
        }
        let wide = img.agglomerate();
        assert_eq!(wide.len(), 4 * 15);
        // wide[i][p*C+j] == img[p][i][j]
        assert_eq!(wide[0 * 15 + 1 * 5 + 3], img.get(1, 0, 3));
        assert_eq!(wide[3 * 15 + 2 * 5 + 0], img.get(2, 3, 0));
        let back = PlanarImage::from_agglomerated(3, 4, 5, &wide).unwrap();
        assert_eq!(back, img);
    }

    #[test]
    fn diff_metrics() {
        let a = PlanarImage::zeros(1, 12, 12);
        let mut b = PlanarImage::zeros(1, 12, 12);
        b.set(0, 0, 0, 2.0); // border pixel: outside the deep interior
        b.set(0, 6, 6, 0.5); // deep interior pixel ([4,8) x [4,8))
        assert_eq!(a.max_abs_diff(&b), 2.0);
        assert_eq!(a.max_abs_diff_deep(&b, 2), 0.5);
    }

    #[test]
    fn deep_diff_on_tiny_planes_is_zero_not_panic() {
        // regression: `d..rows - d` underflowed when rows/cols < 2*halo
        // (reachable since arbitrary odd kernel widths meet tiny planes)
        for (rows, cols, halo) in
            [(3, 3, 2), (4, 4, 2), (8, 8, 2), (1, 1, 1), (12, 3, 2), (3, 12, 2), (5, 5, 3)]
        {
            let a = PlanarImage::zeros(2, rows, cols);
            let mut b = PlanarImage::zeros(2, rows, cols);
            b.set(0, 0, 0, 9.0);
            assert_eq!(a.max_abs_diff_deep(&b, halo), 0.0, "{rows}x{cols} halo {halo}");
        }
        // the boundary case: the smallest plane that *has* an interior
        let a = PlanarImage::zeros(1, 9, 9);
        let mut b = PlanarImage::zeros(1, 9, 9);
        b.set(0, 4, 4, 0.25); // the single interior pixel at d = 4
        assert_eq!(a.max_abs_diff_deep(&b, 2), 0.25);
    }
}
