//! Deterministic synthetic image generators.
//!
//! Stand-ins for the paper's stereo-camera frames (DESIGN.md §1): the
//! convolution is data-independent, so any plane content exercises the
//! same code paths; patterns with known analytic responses (ramps,
//! constants) double as numeric probes.

use crate::util::prng::Prng;

use super::planar::PlanarImage;

/// Available synthetic patterns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pattern {
    /// `v = j` — horizontal linear ramp; Gaussian-invariant on the
    /// interior (blur of a ramp is the ramp), a strong analytic check.
    RampX,
    /// `v = i + j` — diagonal ramp, same invariance both axes.
    RampXY,
    /// 8×8 checkerboard of 0/1 — maximal high-frequency content.
    Checker,
    /// Standard-normal noise (seeded) — the benchmark default.
    Noise,
    /// Filled disc of 1.0 on 0.0 — an edge-rich natural-ish shape.
    Disc,
    /// Constant 0.5 — fixed point of any normalised kernel.
    Constant,
}

impl Pattern {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "ramp-x" => Pattern::RampX,
            "ramp-xy" => Pattern::RampXY,
            "checker" => Pattern::Checker,
            "noise" => Pattern::Noise,
            "disc" => Pattern::Disc,
            "constant" => Pattern::Constant,
            _ => return None,
        })
    }
}

/// Fill one plane. `seed` feeds the PRNG (noise) and phase-shifts the
/// deterministic patterns so planes differ.
pub fn synth_plane(rows: usize, cols: usize, pattern: Pattern, seed: u64) -> Vec<f32> {
    let mut v = vec![0f32; rows * cols];
    match pattern {
        Pattern::RampX => {
            for i in 0..rows {
                for j in 0..cols {
                    v[i * cols + j] = j as f32 + seed as f32;
                }
            }
        }
        Pattern::RampXY => {
            for i in 0..rows {
                for j in 0..cols {
                    v[i * cols + j] = (i + j) as f32 + seed as f32;
                }
            }
        }
        Pattern::Checker => {
            for i in 0..rows {
                for j in 0..cols {
                    v[i * cols + j] = (((i / 8) + (j / 8) + seed as usize) % 2) as f32;
                }
            }
        }
        Pattern::Noise => {
            let mut rng = Prng::new(seed);
            for x in &mut v {
                *x = rng.normal();
            }
        }
        Pattern::Disc => {
            let (cy, cx) = (rows as f32 / 2.0, cols as f32 / 2.0);
            let r2 = (rows.min(cols) as f32 / 3.0).powi(2);
            for i in 0..rows {
                for j in 0..cols {
                    let d2 = (i as f32 - cy).powi(2) + (j as f32 - cx).powi(2);
                    v[i * cols + j] = if d2 < r2 { 1.0 } else { 0.0 };
                }
            }
        }
        Pattern::Constant => {
            v.fill(0.5);
        }
    }
    v
}

/// Build a multi-plane image; plane p uses `seed + p` so planes differ.
pub fn synth_image(planes: usize, rows: usize, cols: usize, pattern: Pattern, seed: u64) -> PlanarImage {
    let mut img = PlanarImage::zeros(planes, rows, cols);
    for p in 0..planes {
        let plane = synth_plane(rows, cols, pattern, seed + p as u64);
        img.plane_mut(p).copy_from_slice(&plane);
    }
    img
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = synth_image(3, 16, 16, Pattern::Noise, 7);
        let b = synth_image(3, 16, 16, Pattern::Noise, 7);
        assert_eq!(a, b);
        let c = synth_image(3, 16, 16, Pattern::Noise, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn planes_differ() {
        let img = synth_image(3, 16, 16, Pattern::Noise, 1);
        assert_ne!(img.plane(0), img.plane(1));
    }

    #[test]
    fn ramp_values() {
        let img = synth_image(1, 4, 6, Pattern::RampX, 0);
        assert_eq!(img.get(0, 0, 0), 0.0);
        assert_eq!(img.get(0, 3, 5), 5.0);
        let img = synth_image(1, 4, 6, Pattern::RampXY, 0);
        assert_eq!(img.get(0, 3, 5), 8.0);
    }

    #[test]
    fn constant_is_constant() {
        let img = synth_image(2, 8, 8, Pattern::Constant, 0);
        assert!(img.data.iter().all(|&v| v == 0.5));
    }

    #[test]
    fn checker_has_both_values() {
        let img = synth_image(1, 32, 32, Pattern::Checker, 0);
        assert!(img.data.iter().any(|&v| v == 0.0));
        assert!(img.data.iter().any(|&v| v == 1.0));
    }

    #[test]
    fn disc_inside_outside() {
        let img = synth_image(1, 60, 60, Pattern::Disc, 0);
        assert_eq!(img.get(0, 30, 30), 1.0);
        assert_eq!(img.get(0, 0, 0), 0.0);
    }

    #[test]
    fn pattern_parse() {
        assert_eq!(Pattern::parse("noise"), Some(Pattern::Noise));
        assert_eq!(Pattern::parse("ramp-x"), Some(Pattern::RampX));
        assert_eq!(Pattern::parse("bogus"), None);
    }
}
