//! Image substrate: planar f32 images, Gaussian kernels, synthetic
//! generators and PGM/PPM I/O.
//!
//! The paper's workload is "3 colour planes" of square images from
//! 1152×1152 to 8748×8748, convolved by a separable 5×5 Gaussian. The
//! stereo rig that produced the original images is not available
//! (DESIGN.md §1), so [`synth`] provides deterministic synthetic planes
//! that exercise the identical code paths — the algorithm is
//! data-independent and memory-fetch bound.

mod kernel;
mod pgm;
mod planar;
mod synth;

pub use kernel::{gaussian_kernel, gaussian_kernel2d, KERNEL_WIDTH};
pub use pgm::{read_pgm, write_pgm, write_ppm};
pub use planar::PlanarImage;
pub use synth::{synth_image, synth_plane, Pattern};

/// The six square sizes of the paper's test set (section 4).
pub const PAPER_SIZES: [usize; 6] = [1152, 1728, 2592, 3888, 5832, 8748];

/// The sizes at which full-image PJRT artifacts are built by default and
/// which the scaled-down host measurements use.
pub const ARTIFACT_SIZES: [usize; 3] = [288, 576, 1152];
