//! Timing statistics, latency histograms and paper-style table
//! rendering.

mod histogram;
mod stats;
mod table;

pub use histogram::Histogram;
pub use stats::{time_reps, SampleSet, Stopwatch};
pub use table::{ms, speedup, Table};
