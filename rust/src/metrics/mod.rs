//! Timing statistics and paper-style table rendering.

mod stats;
mod table;

pub use stats::{time_reps, SampleSet, Stopwatch};
pub use table::{ms, speedup, Table};
