//! Aligned-text / markdown / CSV table renderer for the bench harness.
//!
//! The harness prints the same rows the paper's tables and figures report
//! (DESIGN.md §6); this renderer keeps those dumps readable in a terminal
//! and paste-able into EXPERIMENTS.md.

/// A simple row-oriented table.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Self {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// The table as a JSON object (`{"title", "header", "rows"}`) for
    /// machine consumption of exhibit dumps — `phi-conv … --format json`
    /// and the bench binaries emit this next to the text rendering.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let strs =
            |v: &[String]| Json::Arr(v.iter().map(|s| Json::Str(s.clone())).collect());
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("title".to_string(), Json::Str(self.title.clone()));
        obj.insert("header".to_string(), strs(&self.header));
        obj.insert("rows".to_string(), Json::Arr(self.rows.iter().map(|r| strs(r)).collect()));
        Json::Obj(obj)
    }

    /// Column widths for aligned text output.
    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// Terminal rendering: title, rule, aligned columns.
    pub fn to_text(&self) -> String {
        let w = self.widths();
        let mut s = format!("── {} ──\n", self.title);
        let line = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        s.push_str(&line(&self.header, &w));
        s.push('\n');
        s.push_str(&"─".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        s.push('\n');
        for row in &self.rows {
            s.push_str(&line(row, &w));
            s.push('\n');
        }
        s
    }

    /// GitHub-flavoured markdown (pasted into EXPERIMENTS.md).
    pub fn to_markdown(&self) -> String {
        let mut s = format!("**{}**\n\n", self.title);
        s.push_str(&format!("| {} |\n", self.header.join(" | ")));
        s.push_str(&format!(
            "|{}|\n",
            self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for row in &self.rows {
            s.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        s
    }

    /// CSV (header + rows).
    pub fn to_csv(&self) -> String {
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut s = self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            s.push('\n');
        }
        s
    }
}

/// Format milliseconds like the paper's tables (one decimal place).
pub fn ms(v: f64) -> String {
    format!("{v:.1}")
}

/// Format a speedup factor like the paper ("4.9×").
pub fn speedup(v: f64) -> String {
    format!("{v:.1}×")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("Table 1", &["Image Size", "OpenMP", "GPRM"]);
        t.row(vec!["1152x1152".into(), "3.9".into(), "27.2".into()]);
        t.row(vec!["8748x8748".into(), "195.4".into(), "216.9".into()]);
        t
    }

    #[test]
    fn text_alignment() {
        let txt = sample().to_text();
        assert!(txt.contains("Table 1"));
        let lines: Vec<&str> = txt.lines().collect();
        // header + rule + 2 rows + title line
        assert_eq!(lines.len(), 5);
        // right-aligned numbers share the column end
        assert!(lines[3].ends_with("27.2"));
        assert!(lines[4].ends_with("216.9"));
    }

    #[test]
    fn markdown_shape() {
        let md = sample().to_markdown();
        assert!(md.contains("| Image Size | OpenMP | GPRM |"));
        assert!(md.contains("|---|---|---|"));
        assert!(md.contains("| 8748x8748 | 195.4 | 216.9 |"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["has,comma".into(), "has\"quote".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"has,comma\""));
        assert!(csv.contains("\"has\"\"quote\""));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(ms(3.94), "3.9");
        assert_eq!(speedup(4.87), "4.9×");
    }

    #[test]
    fn json_round_trips() {
        use crate::util::json::Json;
        let parsed = Json::parse(&sample().to_json().to_string()).unwrap();
        assert_eq!(parsed.req_str("title").unwrap(), "Table 1");
        assert_eq!(parsed.req_arr("header").unwrap().len(), 3);
        let rows = parsed.req_arr("rows").unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1].as_arr().unwrap()[2].as_str(), Some("216.9"));
    }
}
