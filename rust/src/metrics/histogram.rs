//! Log-bucketed latency histogram for the load harness.
//!
//! [`SampleSet`](super::SampleSet) keeps every sample and computes exact
//! percentiles by sorting — fine for bench reps, wrong for a load run
//! that may record hundreds of thousands of latencies. `Histogram`
//! spends fixed memory (one `u64` per bucket) and answers percentile
//! queries with bounded relative error instead: buckets are spaced
//! geometrically ([`BUCKETS_PER_DECADE`] per power of ten, covering
//! 1e-4 ms .. 1e5 ms), so any reported quantile is within
//! [`Histogram::relative_resolution`] (~7.5%) of the exact value.
//!
//! Reported percentiles are the geometric midpoint of the selected
//! bucket, clamped to the observed `[min, max]` — which makes a
//! single-sample histogram exact and keeps every quantile inside the
//! recorded range.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Geometric bucket density. 32/decade ⇒ bucket edges grow by
/// 10^(1/32) ≈ 7.46% — the quantile error bound.
const BUCKETS_PER_DECADE: usize = 32;
/// Smallest representable latency: 10^LO_EXP ms (0.1 µs).
const LO_EXP: f64 = -4.0;
/// Decades covered above `LO_EXP` (up to 1e5 ms ≈ 100 s).
const DECADES: usize = 9;
const NUM_BUCKETS: usize = DECADES * BUCKETS_PER_DECADE;

/// Fixed-memory latency histogram (milliseconds by convention).
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            counts: vec![0; NUM_BUCKETS],
            total: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Record one latency. Non-finite values have no bucket and are
    /// dropped (they would otherwise poison min/max/sum); negative
    /// values clamp to the lowest bucket.
    pub fn record(&mut self, ms: f64) {
        if !ms.is_finite() {
            return;
        }
        let v = ms.max(0.0);
        self.counts[Self::bucket_of(v)] += 1;
        self.total += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    fn bucket_of(v: f64) -> usize {
        if v <= 10f64.powf(LO_EXP) {
            return 0;
        }
        let idx = ((v.log10() - LO_EXP) * BUCKETS_PER_DECADE as f64).floor() as isize;
        idx.clamp(0, NUM_BUCKETS as isize - 1) as usize
    }

    /// Geometric midpoint of bucket `i` (the reported quantile value).
    fn bucket_mid(i: usize) -> f64 {
        10f64.powf(LO_EXP + (i as f64 + 0.5) / BUCKETS_PER_DECADE as f64)
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    pub fn mean(&self) -> Option<f64> {
        (self.total > 0).then(|| self.sum / self.total as f64)
    }

    pub fn min(&self) -> Option<f64> {
        (self.total > 0).then_some(self.min)
    }

    pub fn max(&self) -> Option<f64> {
        (self.total > 0).then_some(self.max)
    }

    /// Nearest-rank percentile, `q ∈ [0, 100]`: the midpoint of the
    /// bucket holding the ⌈q/100·n⌉-th smallest sample, clamped to the
    /// observed `[min, max]`. `None` when the histogram is empty — an
    /// empty load run has no latency distribution, and a NaN here
    /// would silently order as "less than" everything in SLO checks.
    ///
    /// Monotone in `q` by construction (cumulative counts only grow),
    /// so p50 ≤ p95 ≤ p99 always holds.
    pub fn percentile(&self, q: f64) -> Option<f64> {
        if self.total == 0 {
            return None;
        }
        let q = q.clamp(0.0, 100.0);
        let rank = ((q / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return Some(Self::bucket_mid(i).clamp(self.min, self.max));
            }
        }
        // cum == total ≥ rank, so the loop always returns; guard anyway
        Some(self.max)
    }

    /// Worst-case relative error of a reported percentile vs the exact
    /// sample value: one bucket's half-width, 10^(1/32) − 1 ≈ 7.46%.
    pub fn relative_resolution() -> f64 {
        10f64.powf(1.0 / BUCKETS_PER_DECADE as f64) - 1.0
    }

    /// Fold another histogram in (per-run aggregation).
    pub fn merge(&mut self, other: &Histogram) {
        if other.total == 0 {
            return;
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Summary as JSON: `n` plus nullable p50/p95/p99/mean/min/max
    /// (null when empty — RFC 8259 has no NaN).
    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| match v {
            Some(x) if x.is_finite() => Json::Num(x),
            _ => Json::Null,
        };
        let mut obj = BTreeMap::new();
        obj.insert("n".to_string(), Json::Num(self.total as f64));
        obj.insert("p50".to_string(), opt(self.percentile(50.0)));
        obj.insert("p95".to_string(), opt(self.percentile(95.0)));
        obj.insert("p99".to_string(), opt(self.percentile(99.0)));
        obj.insert("mean".to_string(), opt(self.mean()));
        obj.insert("min".to_string(), opt(self.min()));
        obj.insert("max".to_string(), opt(self.max()));
        Json::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::SampleSet;
    use crate::util::prng::Prng;

    #[test]
    fn empty_histogram_has_no_percentiles() {
        let h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.percentile(50.0), None);
        assert_eq!(h.mean(), None);
        assert_eq!(h.min(), None);
        assert_eq!(h.max(), None);
    }

    #[test]
    fn single_sample_is_exact_at_every_quantile() {
        // min == max == the sample, so the midpoint clamp collapses
        // every percentile to the exact value
        let mut h = Histogram::new();
        h.record(7.25);
        for q in [0.0, 1.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(h.percentile(q), Some(7.25), "q={q}");
        }
        assert_eq!(h.count(), 1);
        assert_eq!(h.mean(), Some(7.25));
    }

    #[test]
    fn percentiles_are_ordered() {
        let mut h = Histogram::new();
        let mut rng = Prng::new(42);
        for _ in 0..500 {
            h.record(rng.f32() as f64 * 20.0 + 0.01);
        }
        let (p50, p95, p99) =
            (h.percentile(50.0).unwrap(), h.percentile(95.0).unwrap(), h.percentile(99.0).unwrap());
        assert!(p50 <= p95 && p95 <= p99, "p50={p50} p95={p95} p99={p99}");
        assert!(h.min().unwrap() <= p50 && p99 <= h.max().unwrap());
    }

    #[test]
    fn histogram_matches_exact_percentiles_within_resolution() {
        // seeded log-uniform sample spanning three decades: the
        // histogram quantile must stay within one bucket's relative
        // resolution of the exact sorted-sample quantile
        let mut h = Histogram::new();
        let mut exact = SampleSet::new();
        let mut rng = Prng::new(0x1517);
        for _ in 0..1000 {
            let v = 10f64.powf(rng.f32() as f64 * 3.0 - 1.0); // 0.1 .. 100 ms
            h.record(v);
            exact.push(v);
        }
        let tol = Histogram::relative_resolution();
        for q in [50.0, 90.0, 95.0, 99.0] {
            let want = exact.percentile(q);
            let got = h.percentile(q).unwrap();
            let rel = (got - want).abs() / want;
            assert!(rel <= tol, "q={q}: hist {got:.4} vs exact {want:.4} (rel {rel:.4})");
        }
    }

    #[test]
    fn non_finite_samples_are_dropped_and_negatives_clamp() {
        let mut h = Histogram::new();
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        assert!(h.is_empty());
        h.record(-3.0); // clamps to 0 in the lowest bucket
        assert_eq!(h.count(), 1);
        assert_eq!(h.percentile(50.0), Some(0.0));
    }

    #[test]
    fn merge_equals_recording_everything_into_one() {
        let mut rng = Prng::new(9);
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut all = Histogram::new();
        for i in 0..200 {
            let v = rng.f32() as f64 * 50.0;
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            all.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        for q in [50.0, 95.0, 99.0] {
            assert_eq!(a.percentile(q), all.percentile(q), "q={q}");
        }
        assert_eq!(a.min(), all.min());
        assert_eq!(a.max(), all.max());
    }

    #[test]
    fn out_of_range_values_land_in_edge_buckets() {
        let mut h = Histogram::new();
        h.record(1e-9); // below the lowest edge
        h.record(1e9); // above the highest edge
        assert_eq!(h.count(), 2);
        // clamped to observed min/max, so quantiles stay in range
        let p99 = h.percentile(99.0).unwrap();
        assert!(p99 <= 1e9 && p99 >= 1e-9);
    }

    #[test]
    fn json_summary_is_valid_and_nullable() {
        let dumped = Histogram::new().to_json().to_string();
        let parsed = Json::parse(&dumped).expect("empty histogram dumps valid JSON");
        assert_eq!(parsed.req_usize("n").unwrap(), 0);
        assert_eq!(parsed.get("p99"), &Json::Null);
        let mut h = Histogram::new();
        h.record(2.0);
        let parsed = Json::parse(&h.to_json().to_string()).unwrap();
        assert!((parsed.req_f64("p50").unwrap() - 2.0).abs() < 1e-12);
    }
}
