//! Sample collection + robust summary statistics for benchmark timing.

use std::time::Instant;

/// A set of f64 samples (milliseconds by convention).
#[derive(Debug, Clone, Default)]
pub struct SampleSet {
    samples: Vec<f64>,
}

impl SampleSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_vec(samples: Vec<f64>) -> Self {
        Self { samples }
    }

    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    /// Percentile by linear interpolation on the sorted samples,
    /// `q ∈ [0, 100]`.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pos = (q / 100.0) * (s.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (s[hi] - s[lo]) * (pos - lo as f64)
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    /// Benchmark headline number: the median is robust to OS noise spikes
    /// in a way the mean is not.
    pub fn headline_ms(&self) -> f64 {
        self.median()
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} min={:.3} p50={:.3} mean={:.3} p95={:.3} max={:.3} (ms)",
            self.len(),
            self.min(),
            self.median(),
            self.mean(),
            self.percentile(95.0),
            self.max()
        )
    }
}

/// Measure a closure `reps` times (after `warmup` unrecorded runs) and
/// collect per-run milliseconds.
pub fn time_reps<F: FnMut()>(mut f: F, warmup: usize, reps: usize) -> SampleSet {
    for _ in 0..warmup {
        f();
    }
    let mut set = SampleSet::new();
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        set.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    set
}

/// Simple running stopwatch for coordinator latency accounting.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let s = SampleSet::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.median(), 3.0);
        assert!((s.std() - 1.5811).abs() < 1e-3);
    }

    #[test]
    fn percentile_interpolates() {
        let s = SampleSet::from_vec(vec![0.0, 10.0]);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 5.0);
        assert_eq!(s.percentile(100.0), 10.0);
        assert_eq!(s.percentile(25.0), 2.5);
    }

    #[test]
    fn median_robust_to_outlier() {
        let s = SampleSet::from_vec(vec![1.0, 1.0, 1.0, 1.0, 100.0]);
        assert_eq!(s.median(), 1.0);
        assert!(s.mean() > 20.0);
    }

    #[test]
    fn empty_set_is_nan() {
        let s = SampleSet::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
    }

    #[test]
    fn single_sample() {
        let s = SampleSet::from_vec(vec![7.0]);
        assert_eq!(s.median(), 7.0);
        assert_eq!(s.std(), 0.0);
    }

    #[test]
    fn time_reps_counts() {
        let mut n = 0;
        let s = time_reps(|| n += 1, 2, 5);
        assert_eq!(n, 7);
        assert_eq!(s.len(), 5);
        assert!(s.min() >= 0.0);
    }

    #[test]
    fn stopwatch_advances() {
        let w = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(w.ms() >= 1.0);
    }
}
