//! Sample collection + robust summary statistics for benchmark timing.

use std::time::Instant;

use crate::util::json::Json;

/// A set of f64 samples (milliseconds by convention).
#[derive(Debug, Clone, Default)]
pub struct SampleSet {
    samples: Vec<f64>,
}

impl SampleSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_vec(samples: Vec<f64>) -> Self {
        Self { samples }
    }

    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
    }

    /// Append every sample of `other` (stats-shard merging).
    pub fn extend_from(&mut self, other: &SampleSet) {
        self.samples.extend_from_slice(&other.samples);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    /// Percentile by linear interpolation on the sorted samples,
    /// `q ∈ [0, 100]`.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pos = (q / 100.0) * (s.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (s[hi] - s[lo]) * (pos - lo as f64)
        }
    }

    /// Percentile with a structured empty-set result: `None` when
    /// there are no samples, where [`SampleSet::percentile`] returns
    /// NaN. SLO reporting uses this so an empty latency set shows up
    /// as "no data" instead of a NaN that compares false to every
    /// threshold.
    pub fn percentile_checked(&self, q: f64) -> Option<f64> {
        (!self.samples.is_empty()).then(|| self.percentile(q))
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    /// Benchmark headline number: the median is robust to OS noise spikes
    /// in a way the mean is not.
    pub fn headline_ms(&self) -> f64 {
        self.median()
    }

    pub fn summary(&self) -> String {
        // empty sets have no defined min/max/mean (±inf / NaN); never
        // let those leak into human- or machine-readable output
        if self.is_empty() {
            return "n=0 (no samples)".to_string();
        }
        format!(
            "n={} min={:.3} p50={:.3} mean={:.3} p95={:.3} max={:.3} (ms)",
            self.len(),
            self.min(),
            self.median(),
            self.mean(),
            self.percentile(95.0),
            self.max()
        )
    }

    /// The summary as a JSON object. RFC 8259 has no NaN/Infinity, so
    /// the undefined statistics of an empty set (±inf min/max, NaN
    /// mean) are emitted as `null` alongside `n = 0`, rather than the
    /// invalid tokens a naive dump of [`SampleSet::min`] would produce.
    pub fn to_json(&self) -> Json {
        let num = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("n".to_string(), Json::Num(self.len() as f64));
        let fields: [(&str, f64); 5] = [
            ("min", self.min()),
            ("p50", self.median()),
            ("mean", self.mean()),
            ("p95", self.percentile(95.0)),
            ("max", self.max()),
        ];
        for (key, v) in fields {
            obj.insert(key.to_string(), num(v));
        }
        Json::Obj(obj)
    }
}

/// Measure a closure `reps` times (after `warmup` unrecorded runs) and
/// collect per-run milliseconds.
pub fn time_reps<F: FnMut()>(mut f: F, warmup: usize, reps: usize) -> SampleSet {
    for _ in 0..warmup {
        f();
    }
    let mut set = SampleSet::new();
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        set.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    set
}

/// Simple running stopwatch for coordinator latency accounting.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let s = SampleSet::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.median(), 3.0);
        assert!((s.std() - 1.5811).abs() < 1e-3);
    }

    #[test]
    fn percentile_interpolates() {
        let s = SampleSet::from_vec(vec![0.0, 10.0]);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 5.0);
        assert_eq!(s.percentile(100.0), 10.0);
        assert_eq!(s.percentile(25.0), 2.5);
    }

    #[test]
    fn median_robust_to_outlier() {
        let s = SampleSet::from_vec(vec![1.0, 1.0, 1.0, 1.0, 100.0]);
        assert_eq!(s.median(), 1.0);
        assert!(s.mean() > 20.0);
    }

    #[test]
    fn empty_set_is_nan() {
        let s = SampleSet::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
    }

    #[test]
    fn percentile_exact_on_known_samples() {
        // 1..5: pos = q/100·(n−1), linear interpolation between ranks
        let s = SampleSet::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(50.0), 3.0);
        assert!((s.percentile(95.0) - 4.8).abs() < 1e-12);
        assert!((s.percentile(99.0) - 4.96).abs() < 1e-12);
        assert_eq!(s.percentile(100.0), 5.0);
        // order of insertion must not matter
        let shuffled = SampleSet::from_vec(vec![4.0, 1.0, 5.0, 3.0, 2.0]);
        assert_eq!(shuffled.percentile(50.0), 3.0);
    }

    #[test]
    fn percentile_checked_structures_the_edge_cases() {
        // n=0: structured None (the raw query is NaN, no panic)
        let empty = SampleSet::new();
        assert_eq!(empty.percentile_checked(50.0), None);
        assert_eq!(empty.percentile_checked(99.0), None);
        // n=1: every quantile is the lone sample
        let one = SampleSet::from_vec(vec![7.0]);
        for q in [0.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(one.percentile_checked(q), Some(7.0), "q={q}");
        }
    }

    #[test]
    fn percentiles_are_ordered() {
        let mut s = SampleSet::new();
        let mut x = 1u64;
        for _ in 0..257 {
            // deterministic scramble (splitmix-style) — no RNG import
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            s.push((x >> 40) as f64 / 1e3);
        }
        let (p50, p95, p99) =
            (s.percentile(50.0), s.percentile(95.0), s.percentile(99.0));
        assert!(p50 <= p95 && p95 <= p99, "p50={p50} p95={p95} p99={p99}");
        assert!(s.min() <= p50 && p99 <= s.max());
    }

    #[test]
    fn empty_set_summary_is_guarded() {
        let s = SampleSet::new();
        let text = s.summary();
        assert_eq!(text, "n=0 (no samples)");
        assert!(!text.contains("inf") && !text.contains("NaN"), "got: {text}");
    }

    #[test]
    fn empty_set_json_round_trips() {
        // an empty stats dump must be *valid* JSON: ±inf/NaN have no
        // JSON spelling and used to serialize as invalid tokens
        let dumped = SampleSet::new().to_json().to_string();
        let parsed = Json::parse(&dumped).expect("empty-set dump must be parseable JSON");
        assert_eq!(parsed.req_usize("n").unwrap(), 0);
        assert_eq!(parsed.get("min"), &Json::Null);
        assert_eq!(parsed.get("mean"), &Json::Null);
        assert_eq!(parsed.get("max"), &Json::Null);
    }

    #[test]
    fn populated_json_round_trips() {
        let s = SampleSet::from_vec(vec![1.0, 2.0, 3.0]);
        let parsed = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(parsed.req_usize("n").unwrap(), 3);
        assert!((parsed.req_f64("min").unwrap() - 1.0).abs() < 1e-12);
        assert!((parsed.req_f64("mean").unwrap() - 2.0).abs() < 1e-12);
        assert!((parsed.req_f64("max").unwrap() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn extend_from_concatenates() {
        let mut a = SampleSet::from_vec(vec![1.0, 2.0]);
        let b = SampleSet::from_vec(vec![3.0]);
        a.extend_from(&b);
        assert_eq!(a.samples(), &[1.0, 2.0, 3.0]);
        a.extend_from(&SampleSet::new());
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn single_sample() {
        let s = SampleSet::from_vec(vec![7.0]);
        assert_eq!(s.median(), 7.0);
        assert_eq!(s.std(), 0.0);
    }

    #[test]
    fn time_reps_counts() {
        let mut n = 0;
        let s = time_reps(|| n += 1, 2, 5);
        assert_eq!(n, 7);
        assert_eq!(s.len(), 5);
        assert!(s.min() >= 0.0);
    }

    #[test]
    fn stopwatch_advances() {
        let w = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(w.ms() >= 1.0);
    }
}
