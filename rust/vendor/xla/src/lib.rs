//! API stub for the vendored `xla` (xla_extension 0.5.1) PJRT bindings.
//!
//! The real crate links the multi-gigabyte xla_extension closure, which
//! this offline environment does not ship. This stub mirrors exactly the
//! API surface `phi-conv`'s `runtime` layer uses, so the crate
//! type-checks and builds with `--features pjrt`; every operation that
//! would touch PJRT returns [`Error::Unavailable`] at runtime instead.
//!
//! To run the real bridge, replace this directory with the vendored
//! `xla` crate (same package name and API) and rebuild — no `phi-conv`
//! source change is needed.

use std::fmt;

/// Error surfaced by every stubbed PJRT operation.
#[derive(Debug)]
pub enum Error {
    /// The vendored xla_extension closure is not present in this build.
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla stub: {what} requires the vendored xla_extension closure, \
                 which is not present in this offline build"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// An HLO module parsed from text (stub: never materialises).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

/// The PJRT client (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// A compiled executable (stub: never materialises).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// A device buffer returned by execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A host literal (stub: shape-less placeholder).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1(_values: &[f32]) -> Self {
        Self { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_operations_report_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("xla stub"));
    }
}
