//! Bench: fused vs unfused two-pass — per-image time **and** estimated
//! bytes moved through main memory.
//!
//! The unfused separable pipeline writes a full-plane horizontal
//! intermediate and re-reads it vertically, so every image crosses
//! memory twice; the fused rolling row-ring keeps the intermediate in
//! an O(width×cols) per-worker ring, halving plane traffic. On
//! bandwidth-bound hardware (the Xeon Phi of the source paper; Hofmann
//! et al. in PAPERS.md make the general case) the traffic column — not
//! the FLOP count — is what explains the speedup, so this bench prints
//! both, plus the same table as JSON for machine consumption.
//!
//! `cargo bench --bench fused` — env overrides:
//!   PHI_BENCH_SIZES=288,576   PHI_BENCH_REPS=5   PHI_BENCH_THREADS=8

const EXHIBIT: &str = "fused";

use phi_conv::config::RunConfig;
use phi_conv::harness;

fn main() {
    let cfg = RunConfig::from_bench_env();
    for t in harness::run_measured(EXHIBIT, &cfg).unwrap() {
        println!("{}", t.to_text());
        println!("{}", t.to_json());
    }
}
