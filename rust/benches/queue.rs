//! Bench: coordinator intake path — bounded admission-queue throughput
//! and the sharded-stats hot path versus the designs they replaced.
//!
//! Two tables:
//! 1. raw queue throughput: the hand-rolled `AdmissionQueue` ring
//!    buffer against the old intake shape (unbounded `mpsc` channel
//!    drained through one `Mutex<Receiver>`), across producer ×
//!    consumer mixes;
//! 2. per-request stats accounting: one global `Mutex` taken by every
//!    executor (the old design) against per-executor shards merged
//!    only at read time.
//!
//! `cargo bench --bench queue` — env overrides:
//!   PHI_QUEUE_BENCH_ITEMS=200000   PHI_QUEUE_BENCH_OPS=400000
//!
//! Numbers are ops/ms (higher is better); these are contention
//! microbenches, so expect run-to-run noise — compare magnitudes, not
//! single percents.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use phi_conv::coordinator::{AdmissionQueue, CoordinatorStats, Pop};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Producer/consumer sweep over the bounded ring buffer.
fn ring_throughput(producers: usize, consumers: usize, items: usize) -> f64 {
    let q = Arc::new(AdmissionQueue::new(1024));
    let per = items / producers;
    let t0 = Instant::now();
    let cons: Vec<_> = (0..consumers)
        .map(|_| {
            let q = q.clone();
            std::thread::spawn(move || {
                let mut n = 0usize;
                loop {
                    match q.pop() {
                        Pop::Job(_) | Pop::Expired(_) => n += 1,
                        Pop::Closed => return n,
                    }
                }
            })
        })
        .collect();
    let prod: Vec<_> = (0..producers)
        .map(|p| {
            let q = q.clone();
            std::thread::spawn(move || {
                for i in 0..per {
                    q.push((p * per + i) as u64, None).ok();
                }
            })
        })
        .collect();
    for h in prod {
        h.join().unwrap();
    }
    q.close();
    let total: usize = cons.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, per * producers, "every item delivered");
    total as f64 / (t0.elapsed().as_secs_f64() * 1e3)
}

/// The pre-PR intake shape: unbounded channel, all consumers
/// serializing on one `Mutex<Receiver>` around a blocking `recv()`.
fn channel_throughput(producers: usize, consumers: usize, items: usize) -> f64 {
    let (tx, rx) = mpsc::channel::<u64>();
    let rx = Arc::new(Mutex::new(rx));
    let per = items / producers;
    let t0 = Instant::now();
    let cons: Vec<_> = (0..consumers)
        .map(|_| {
            let rx = rx.clone();
            std::thread::spawn(move || {
                let mut n = 0usize;
                loop {
                    match rx.lock().unwrap().recv() {
                        Ok(_) => n += 1,
                        Err(_) => return n,
                    }
                }
            })
        })
        .collect();
    let prod: Vec<_> = (0..producers)
        .map(|p| {
            let tx = tx.clone();
            std::thread::spawn(move || {
                for i in 0..per {
                    tx.send((p * per + i) as u64).unwrap();
                }
            })
        })
        .collect();
    for h in prod {
        h.join().unwrap();
    }
    drop(tx); // close: consumers drain and exit
    let total: usize = cons.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, per * producers, "every item delivered");
    total as f64 / (t0.elapsed().as_secs_f64() * 1e3)
}

/// Every executor takes one global stats lock per request (old design).
fn stats_single_lock(threads: usize, ops: usize) -> f64 {
    let stats = Arc::new(Mutex::new(CoordinatorStats::default()));
    let per = ops / threads;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..threads)
        .map(|_| {
            let stats = stats.clone();
            std::thread::spawn(move || {
                for i in 0..per {
                    let mut st = stats.lock().unwrap();
                    st.served += 1;
                    st.queue_ms.push(i as f64);
                    st.service_ms.entry("openmp").or_default().push(i as f64);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let st = stats.lock().unwrap();
    assert_eq!(st.served as usize, per * threads);
    (per * threads) as f64 / (t0.elapsed().as_secs_f64() * 1e3)
}

/// Each executor owns a shard; the shards merge only at read time
/// (the design the coordinator now uses).
fn stats_sharded(threads: usize, ops: usize) -> f64 {
    let shards: Arc<Vec<Mutex<CoordinatorStats>>> =
        Arc::new((0..threads).map(|_| Mutex::new(CoordinatorStats::default())).collect());
    let per = ops / threads;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let shards = shards.clone();
            std::thread::spawn(move || {
                for i in 0..per {
                    let mut st = shards[t].lock().unwrap();
                    st.served += 1;
                    st.queue_ms.push(i as f64);
                    st.service_ms.entry("openmp").or_default().push(i as f64);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    // the read-side merge (what `Coordinator::stats` does)
    let mut total = CoordinatorStats::default();
    for shard in shards.iter() {
        total.merge(&shard.lock().unwrap());
    }
    assert_eq!(total.served as usize, per * threads);
    (per * threads) as f64 / (t0.elapsed().as_secs_f64() * 1e3)
}

fn main() {
    let items = env_usize("PHI_QUEUE_BENCH_ITEMS", 200_000);
    let ops = env_usize("PHI_QUEUE_BENCH_OPS", 400_000);

    let mut t = phi_conv::metrics::Table::new(
        format!("Intake throughput, {items} items (ops/ms): bounded ring vs mpsc+Mutex<Receiver>"),
        &["producers x consumers", "ring ops/ms", "channel ops/ms", "ring gain"],
    );
    for (p, c) in [(1, 1), (1, 4), (4, 1), (4, 4), (8, 4)] {
        let ring = ring_throughput(p, c, items);
        let chan = channel_throughput(p, c, items);
        t.row(vec![
            format!("{p} x {c}"),
            format!("{ring:.0}"),
            format!("{chan:.0}"),
            format!("{:.2}x", ring / chan),
        ]);
    }
    println!("{}", t.to_text());

    let mut t = phi_conv::metrics::Table::new(
        format!("Per-request stats accounting, {ops} ops (ops/ms): global lock vs shards"),
        &["executors", "single-lock ops/ms", "sharded ops/ms", "sharded gain"],
    );
    for threads in [1, 2, 4, 8] {
        let single = stats_single_lock(threads, ops);
        let sharded = stats_sharded(threads, ops);
        t.row(vec![
            format!("{threads}"),
            format!("{single:.0}"),
            format!("{sharded:.0}"),
            format!("{:.2}x", sharded / single),
        ]);
    }
    println!("{}", t.to_text());
}
