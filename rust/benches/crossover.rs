//! Bench: the direct-2D vs FFT kernel-class crossover.
//!
//! Sweeps odd kernel widths 3..=63 on one image size, timing the banded
//! direct 2-D engine against the radix-2 FFT convolver, and reports the
//! first width where the FFT wins — the measured crossover the learned
//! cost model is expected to place on its own. Emits the sweep as
//! `BENCH_crossover.json` so future perf PRs have a trajectory file for
//! both engines.
//!
//! Correctness is asserted, timing is only reported: at every width the
//! two classes must agree within 1e-4 (the FFT runs f64 internally, the
//! direct engines accumulate f32), and the separable two-pass output
//! anchors the direct engine within 1e-6. Which width wins is a column
//! to read, not a test to fail — the crossover moves with the host.
//!
//! `cargo bench --bench crossover` — env overrides:
//!   PHI_BENCH_SIZES=256 (last entry is used)  PHI_BENCH_REPS=5
//!   PHI_BENCH_THREADS=8  PHI_CROSSOVER_JSON=BENCH_crossover.json (empty = skip)

use phi_conv::config::{default_threads, RunConfig};
use phi_conv::image::synth_image;
use phi_conv::metrics::{time_reps, Table};
use phi_conv::models::OpenMpModel;
use phi_conv::plan::{ConvPlan, KernelClass, KernelSpec, ScratchArena};
use phi_conv::util::json::Json;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let threads = env_usize("PHI_BENCH_THREADS", default_threads());
    let reps = env_usize("PHI_BENCH_REPS", 5);
    let cfg = RunConfig { threads, reps, ..RunConfig::default() };
    let size = std::env::var("PHI_BENCH_SIZES")
        .ok()
        .and_then(|v| v.split(',').last().and_then(|s| s.trim().parse().ok()))
        .unwrap_or(256usize);

    let img = synth_image(cfg.planes, size, size, cfg.pattern, cfg.seed);
    let model = OpenMpModel::new(threads);
    let mut arena = ScratchArena::new();
    let build = |width: usize, class: KernelClass| {
        let sigma = (width as f64 / 5.0).max(0.5);
        ConvPlan::builder()
            .kernel(KernelSpec::new(width, sigma))
            .kernel_class(class)
            .shape(cfg.planes, size, size)
            .build()
            .expect("crossover plan")
    };

    let mut t = Table::new(
        format!(
            "kernel-class crossover: {}x{size}x{size}, {threads} threads, median of {reps}",
            cfg.planes
        ),
        &["Width", "direct2d ms", "fft ms", "winner"],
    );
    let mut sweep = Vec::new();
    let mut crossover: Option<usize> = None;
    for width in (3..=63usize).step_by(4) {
        if width >= size {
            break;
        }
        let direct = build(width, KernelClass::Direct2d);
        let fft = build(width, KernelClass::Fft);
        let sep = {
            let sigma = (width as f64 / 5.0).max(0.5);
            ConvPlan::builder()
                .kernel(KernelSpec::new(width, sigma))
                .shape(cfg.planes, size, size)
                .build()
                .expect("separable plan")
        };

        let mut got_d = direct.execute_on(&model, &img, &mut arena).expect("direct2d");
        let mut got_f = fft.execute_on(&model, &img, &mut arena).expect("fft");
        let want = sep.execute(&img, &mut arena).expect("two-pass");
        let d = got_d.max_abs_diff(&want);
        assert!(d < 1e-6, "width {width}: direct2d vs two-pass diff {d:e}");
        let f = got_f.max_abs_diff(&got_d);
        assert!(f < 1e-4, "width {width}: fft vs direct2d diff {f:e}");

        let t_d = time_reps(
            || got_d = direct.execute_on(&model, &img, &mut arena).expect("direct2d"),
            cfg.warmup,
            reps,
        )
        .median();
        let t_f = time_reps(
            || got_f = fft.execute_on(&model, &img, &mut arena).expect("fft"),
            cfg.warmup,
            reps,
        )
        .median();
        if crossover.is_none() && t_f < t_d {
            crossover = Some(width);
        }
        t.row(vec![
            width.to_string(),
            format!("{t_d:.3}"),
            format!("{t_f:.3}"),
            if t_f < t_d { "fft" } else { "direct2d" }.to_string(),
        ]);
        let mut row = std::collections::BTreeMap::new();
        row.insert("width".to_string(), Json::Num(width as f64));
        row.insert("direct_ms".to_string(), Json::Num(t_d));
        row.insert("fft_ms".to_string(), Json::Num(t_f));
        sweep.push(Json::Obj(row));
    }
    println!("{}", t.to_text());
    match crossover {
        Some(w) => println!("measured crossover width: {w}"),
        None => println!("measured crossover width: none within the sweep"),
    }

    let path =
        std::env::var("PHI_CROSSOVER_JSON").unwrap_or_else(|_| "BENCH_crossover.json".to_string());
    if !path.is_empty() {
        let mut root = std::collections::BTreeMap::new();
        root.insert("bench".to_string(), Json::Str("crossover".to_string()));
        root.insert("provenance".to_string(), Json::Str("measured".to_string()));
        root.insert("threads".to_string(), Json::Num(threads as f64));
        root.insert("planes".to_string(), Json::Num(cfg.planes as f64));
        root.insert("size".to_string(), Json::Num(size as f64));
        root.insert("reps".to_string(), Json::Num(reps as f64));
        root.insert(
            "crossover_width".to_string(),
            crossover.map(|w| Json::Num(w as f64)).unwrap_or(Json::Null),
        );
        root.insert("sweep".to_string(), Json::Arr(sweep));
        let json = Json::Obj(root);
        std::fs::write(&path, format!("{json}\n"))
            .unwrap_or_else(|e| panic!("writing {path}: {e}"));
        println!("wrote {path}");
    }
}
