//! Bench: Table 1 — effect of vectorisation on the parallel two-pass, 3 models,
//! simulated at the paper sizes and measured on this host.
//!
//! Bounds-check elision note (ISSUE 5 satellite): `vert_band_simd`'s
//! inner loop used to be an indexed sweep — `for jj in 0..w { out[jj] =
//! s0[jj]*k[0] + … }` — where LLVM must prove five slice bounds per
//! iteration before vectorising. It is now a zipped iterator over the
//! five row slices (the same shape as the `windows()`-based horizontal
//! engines and the generic `_w` verticals, which were already zipped),
//! so no bounds checks survive into the loop body. The SIMD columns of
//! this table are where the before/after shows up; the arithmetic
//! expression and tap order are unchanged, so outputs are bitwise
//! identical.
//!
//! `cargo bench --bench vectorisation` — env overrides:
//!   PHI_BENCH_SIZES=288,576   PHI_BENCH_REPS=5   PHI_BENCH_THREADS=8

const EXHIBIT: &str = "table1";

use phi_conv::config::RunConfig;
use phi_conv::harness;

fn main() {
    let cfg = RunConfig::from_bench_env();
    for t in harness::simulated(EXHIBIT).unwrap() {
        println!("{}", t.to_text());
    }
    for t in harness::run_measured(EXHIBIT, &cfg).unwrap() {
        println!("{}", t.to_text());
    }
}
