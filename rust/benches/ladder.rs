//! Bench: Figure 1 — the naive → parallelised-optimised ladder (copy-back baseline),
//! simulated at the paper sizes and measured on this host.
//!
//! `cargo bench --bench ladder` — env overrides:
//!   PHI_BENCH_SIZES=288,576   PHI_BENCH_REPS=5   PHI_BENCH_THREADS=8

const EXHIBIT: &str = "fig1";

use phi_conv::config::RunConfig;
use phi_conv::harness;

fn main() {
    let cfg = RunConfig::from_bench_env();
    for t in harness::simulated(EXHIBIT).unwrap() {
        println!("{}", t.to_text());
    }
    for t in harness::run_measured(EXHIBIT, &cfg).unwrap() {
        println!("{}", t.to_text());
    }
}
