//! Bench: cost-model prediction accuracy on held-out shapes.
//!
//! Trains the regression cost model from a real autotune sweep (several
//! sizes × kernel widths, all candidate tiles/fusion states), then
//! scores it on a holdout shape grid **disjoint from the training
//! sweep**: for each (model, holdout size) the predicted-cheapest
//! candidate is built and measured, and the table reports predicted vs
//! measured milliseconds with relative error. Accuracy is a column to
//! read, not a test to fail — timing asserts would flake on loaded CI
//! runners. What *is* asserted is the persistence contract: the written
//! `BENCH_costmodel.json` reloads, carries at least one finite-R² group,
//! and predicts bitwise-identically to the in-memory fit.
//!
//! `cargo bench --bench costmodel` — env overrides:
//!   PHI_TUNE_SMOKE=1    small sizes + 2 reps (the CI verify leg)
//!   PHI_BENCH_THREADS=8 PHI_BENCH_REPS=5 PHI_BENCH_WARMUP=2
//!   PHI_COSTMODEL_JSON=BENCH_costmodel.json   (empty string = don't write)

use std::collections::BTreeMap;
use std::path::Path;

use phi_conv::autotune::{sweep_shape_sampled, TuningTable};
use phi_conv::config::RunConfig;
use phi_conv::costmodel::{accuracy_table, CostModel, Sample};
use phi_conv::models::TileSpec;
use phi_conv::util::json::Json;

fn main() {
    let smoke = std::env::var("PHI_TUNE_SMOKE").map(|v| v == "1").unwrap_or(false);
    let mut cfg = RunConfig::from_bench_env();
    let (train_sizes, widths): (Vec<usize>, Vec<usize>) = if smoke {
        cfg.reps = 2;
        (vec![40, 56, 72, 96], vec![3, 5])
    } else {
        (vec![96, 160, 224, 288], vec![3, 5, 7])
    };
    // holdout: 3/4 of each training size, excluding anything trained on
    let holdout: Vec<usize> = train_sizes
        .iter()
        .map(|s| s * 3 / 4)
        .filter(|s| *s >= 16 && !train_sizes.contains(s))
        .collect();
    eprintln!(
        "training sweep: sizes {train_sizes:?} × widths {widths:?}, {} threads, {} reps; holdout {holdout:?}",
        cfg.threads, cfg.reps
    );

    let mut samples: Vec<Sample> = Vec::new();
    let mut table = TuningTable::new();
    for &w in &widths {
        let mut cfg_w = cfg.clone();
        cfg_w.kernel_width = w;
        for &size in &train_sizes {
            sweep_shape_sampled(&cfg_w, size, &mut table, &mut samples)
                .unwrap_or_else(|e| panic!("sweep {size} w{w}: {e:#}"));
        }
    }
    eprintln!("collected {} samples", samples.len());

    let model = CostModel::fit(samples, cfg.r2_min);
    println!("{}", model.to_table().to_text());

    let acc = accuracy_table(&cfg, &model, &holdout).expect("accuracy table");
    println!("{}", acc.to_text());
    println!("{}", acc.to_json());

    let path =
        std::env::var("PHI_COSTMODEL_JSON").unwrap_or_else(|_| "BENCH_costmodel.json".into());
    if path.is_empty() {
        return;
    }
    let mut obj = match model.to_json() {
        Json::Obj(m) => m,
        other => panic!("costmodel JSON root must be an object, got {other}"),
    };
    obj.insert(
        "provenance".to_string(),
        Json::Str(format!(
            "cargo bench --bench costmodel (smoke={smoke}), {} threads, {} reps",
            cfg.threads, cfg.reps
        )),
    );
    obj.insert(
        "train_sizes".to_string(),
        Json::Arr(train_sizes.iter().map(|s| Json::Num(*s as f64)).collect()),
    );
    obj.insert(
        "holdout_sizes".to_string(),
        Json::Arr(holdout.iter().map(|s| Json::Num(*s as f64)).collect()),
    );
    obj.insert("accuracy".to_string(), acc.to_json());
    std::fs::write(&path, format!("{}\n", Json::Obj(obj)))
        .unwrap_or_else(|e| panic!("writing {path}: {e}"));
    println!("wrote {path}");

    // persistence contract, asserted on the real artifact: it reloads
    // (extra provenance keys are ignored), carries at least one
    // finite-R² group, and predicts bitwise-identically.
    let reloaded = CostModel::load(Path::new(&path)).expect("reload written artifact");
    assert!(
        reloaded
            .groups()
            .iter()
            .any(|g| g.fit.as_ref().is_some_and(|f| f.r2.is_finite())),
        "written artifact must carry at least one finite-R² model"
    );
    let probe_tile = TileSpec::new(32, 32);
    for g in model.groups() {
        let tile = if g.tiled { Some(probe_tile) } else { None };
        let a = model.predict_ms(&g.model, g.fused, tile, 3, 123, 131, cfg.kernel_width, cfg.threads);
        let b = reloaded.predict_ms(&g.model, g.fused, tile, 3, 123, 131, cfg.kernel_width, cfg.threads);
        assert_eq!(
            a.map(f64::to_bits),
            b.map(f64::to_bits),
            "{} fused={} tiled={}: save/load must preserve predictions bitwise",
            g.model,
            g.fused,
            g.tiled
        );
    }
    println!("save/load self-check: predictions bitwise-identical after round-trip");
}
