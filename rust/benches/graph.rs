//! Bench: multi-stage chains — per-chain time **and** estimated bytes
//! moved for 2/3/4-stage chains, fused inter-stage streaming vs
//! materialised intermediates.
//!
//! A k-stage materialised chain crosses memory 2k times (each stage
//! reads its input plane and writes its output plane); the streamed
//! cascade crosses twice, so the traffic column scales the PR 5 fusion
//! argument by chain length. Correctness is asserted before timing:
//! streamed and materialised execution must agree within 1e-6.
//!
//! `cargo bench --bench graph` — env overrides:
//!   PHI_GRAPH_SIZE=288   PHI_BENCH_REPS=5   PHI_BENCH_THREADS=8
//!   PHI_GRAPH_JSON=BENCH_graph.json   (empty string = don't write)

use std::collections::BTreeMap;

use phi_conv::config::default_threads;
use phi_conv::image::{synth_image, Pattern};
use phi_conv::metrics::{time_reps, Table};
use phi_conv::models::OpenMpModel;
use phi_conv::plan::{FilterGraph, KernelSpec, ScratchArena};
use phi_conv::util::json::Json;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn chain_widths(n: usize) -> &'static [usize] {
    match n {
        2 => &[5, 9],
        3 => &[3, 5, 9],
        _ => &[3, 5, 7, 9],
    }
}

fn chain(n: usize, planes: usize, size: usize, streamed: bool) -> FilterGraph {
    let mut b = FilterGraph::builder().shape(planes, size, size);
    for (i, &w) in chain_widths(n).iter().enumerate() {
        b = b.stage(&format!("s{i}"), KernelSpec::new(w, 0.4 + w as f64 / 4.0));
        if !streamed {
            b = b.materialized();
        }
    }
    b.build().expect("chain builds")
}

fn main() {
    let size = env_usize("PHI_GRAPH_SIZE", 288);
    let reps = env_usize("PHI_BENCH_REPS", 5);
    let threads = env_usize("PHI_BENCH_THREADS", default_threads());
    let planes = 3;
    let img = synth_image(planes, size, size, Pattern::Noise, 42);
    let model = OpenMpModel::new(threads);
    let mut arena = ScratchArena::new();

    let mut t = Table::new(
        format!("FilterGraph chains on {planes}x{size}x{size}, {threads} threads, {reps} reps"),
        &["stages", "mode", "ms (median)", "est MiB moved", "traffic saved"],
    );
    for n in [2usize, 3, 4] {
        let s = chain(n, planes, size, true);
        let m = chain(n, planes, size, false);
        // correctness before timing
        let a = s.execute_on(&model, &img, &mut arena).expect("streamed");
        let b = m.execute_on(&model, &img, &mut arena).expect("materialized");
        let d = a[0].max_abs_diff(&b[0]);
        assert!(d <= 1e-6, "{n} stages: streamed vs materialized diverged by {d}");

        let ts = time_reps(
            || {
                s.execute_on(&model, &img, &mut arena).expect("streamed");
            },
            1,
            reps,
        )
        .median();
        let tm = time_reps(
            || {
                m.execute_on(&model, &img, &mut arena).expect("materialized");
            },
            1,
            reps,
        )
        .median();
        let tr = s.traffic_estimate();
        let (mb_s, mb_m) = (tr.total.total_mb(), tr.materialized_total.total_mb());
        t.row(vec![
            format!("{n}"),
            "streamed".to_string(),
            format!("{ts:.3}"),
            format!("{mb_s:.2}"),
            format!("{:.0}%", (1.0 - mb_s / mb_m) * 100.0),
        ]);
        t.row(vec![
            format!("{n}"),
            "materialized".to_string(),
            format!("{tm:.3}"),
            format!("{mb_m:.2}"),
            "-".to_string(),
        ]);
    }
    println!("{}", t.to_text());
    println!("{}", t.to_json());

    let path = std::env::var("PHI_GRAPH_JSON").unwrap_or_else(|_| "BENCH_graph.json".into());
    if !path.is_empty() {
        let mut obj = BTreeMap::new();
        obj.insert("bench".to_string(), Json::Str("graph".into()));
        obj.insert("shape".to_string(), Json::Str(format!("{planes}x{size}x{size}")));
        obj.insert("threads".to_string(), Json::Num(threads as f64));
        obj.insert("reps".to_string(), Json::Num(reps as f64));
        obj.insert("chains".to_string(), t.to_json());
        std::fs::write(&path, format!("{}\n", Json::Obj(obj)))
            .unwrap_or_else(|e| panic!("writing {path}: {e}"));
        println!("wrote {path}");
    }
}
