//! Bench: tiled 2-D dispatch + task agglomeration, auto-tuned.
//!
//! Sweeps the tile/agglomeration candidates per execution model at each
//! size (the paper's Fig. 3 experiment generalised from 3R×C to
//! arbitrary tiles), prints the per-size sweep tables, and finishes with
//! the tuned-winner summary — the tuned tile beats or equals the untiled
//! row-partition baseline by construction (the baseline is always a
//! candidate).
//!
//! `cargo bench --bench tiling` — env overrides:
//!   PHI_BENCH_SIZES=288,576   PHI_BENCH_REPS=5   PHI_BENCH_THREADS=8

use phi_conv::autotune::{sweep_shape, TuningTable};
use phi_conv::config::RunConfig;

fn main() {
    let cfg = RunConfig::from_bench_env();
    let mut table = TuningTable::new();
    for &size in &cfg.sizes {
        println!("{}", sweep_shape(&cfg, size, &mut table).unwrap().to_text());
    }
    println!("{}", table.to_table().to_text());
}
