//! Bench: coordinator serving throughput — plan-keyed batching vs the
//! unbatched baseline.
//!
//! The paper's agglomeration result (amortise per-task overhead across
//! more work) applied to the serving layer: one executor drains its
//! queue either one request at a time (`batch_max = 1`, the PR 3
//! behaviour) or in `PlanKey`-coalesced batches served through a single
//! `ConvPlan::execute_batch` call — one plan lookup, one warm arena,
//! one dispatch ramp per batch. Reports requests/sec per `batch_max`
//! plus a batch-size histogram, text + JSON, and writes the repo's
//! first `BENCH_*.json` perf-trajectory file.
//!
//! Correctness is asserted, timing is only reported: every batched
//! response is compared bitwise against the unbatched baseline, and a
//! skewed-mix leg checks a rare shape is served within its deadline
//! behind a hot-shape flood. Timing asserts would flake on loaded CI
//! runners, so throughput is a column to read, not a test to fail.
//!
//! `cargo bench --bench serving` — env overrides:
//!   PHI_SERVING_REQS=48   PHI_SERVING_SIZE=160   PHI_BENCH_THREADS=8
//!   PHI_SERVING_JSON=BENCH_serving.json   (empty string = don't write)

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use phi_conv::config::{default_threads, RunConfig};
use phi_conv::coordinator::{Backend, ConvRequest, Coordinator, RoutePolicy};
use phi_conv::image::{synth_image, Pattern, PlanarImage};
use phi_conv::metrics::Table;
use phi_conv::util::json::Json;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

struct RunResult {
    wall_ms: f64,
    /// responses in submission order (bitwise-compared across runs)
    images: Vec<PlanarImage>,
    /// batch_len -> number of responses served at that coalescing level
    hist: BTreeMap<usize, usize>,
}

/// Serve every image through a fresh 1-executor coordinator at the
/// given `batch_max`; one executor makes the batched-vs-single
/// comparison clean (no cross-shard scheduling noise).
fn run_once(batch_max: usize, imgs: &[PlanarImage], threads: usize) -> RunResult {
    let cfg = RunConfig {
        threads,
        queue_capacity: imgs.len() + 8,
        batch_max,
        ..RunConfig::default()
    };
    let c = Coordinator::new(&cfg, RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false)
        .expect("coordinator");
    let t0 = Instant::now();
    let rxs: Vec<_> = imgs
        .iter()
        .enumerate()
        .map(|(i, img)| c.submit(ConvRequest::new(i as u64, img.clone())).expect("admitted"))
        .collect();
    let mut images = Vec::with_capacity(rxs.len());
    let mut hist = BTreeMap::new();
    for rx in rxs {
        let resp = rx.recv().expect("reply").expect("served");
        *hist.entry(resp.batch_len).or_insert(0usize) += 1;
        images.push(resp.image);
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(c.stats().errors, 0, "no serve errors");
    RunResult { wall_ms, images, hist }
}

/// The fairness backstop under a skewed mix: a minority shape queued
/// behind a hot-shape flood must still be served within its deadline —
/// coalescing removes only matching jobs and preserves FIFO for the
/// rest, so a rare `PlanKey` is never starved.
fn fairness_leg(size: usize, threads: usize) {
    let cfg =
        RunConfig { threads, queue_capacity: 64, batch_max: 8, ..RunConfig::default() };
    let c = Coordinator::new(&cfg, RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false)
        .expect("coordinator");
    let hot = synth_image(3, size, size, Pattern::Noise, 1);
    let rare = synth_image(3, size / 2, size / 2 + 4, Pattern::Noise, 2);
    let mut rxs = Vec::new();
    for i in 0..32u64 {
        let req = if i % 8 == 7 {
            ConvRequest::new(i, rare.clone()).with_deadline(Duration::from_secs(60))
        } else {
            ConvRequest::new(i, hot.clone())
        };
        rxs.push(c.submit(req).expect("admitted"));
    }
    for rx in rxs {
        rx.recv().expect("reply").expect("rare shape must not starve behind the hot flood");
    }
    assert_eq!(c.stats().expired, 0, "no deadline lapses in the skewed mix");
}

fn main() {
    let reqs = env_usize("PHI_SERVING_REQS", 48);
    let size = env_usize("PHI_SERVING_SIZE", 160);
    let threads = env_usize("PHI_BENCH_THREADS", default_threads());
    let imgs: Vec<PlanarImage> = (0..reqs)
        .map(|i| synth_image(3, size, size, Pattern::Noise, 1000 + i as u64))
        .collect();

    let base = run_once(1, &imgs, threads);
    let base_rps = reqs as f64 / (base.wall_ms / 1e3);
    let mut results = vec![(1usize, base)];
    for bm in [4usize, 8] {
        let r = run_once(bm, &imgs, threads);
        for (i, (got, want)) in r.images.iter().zip(&results[0].1.images).enumerate() {
            assert_eq!(got, want, "request {i}: batched pixels must equal singly-served");
        }
        results.push((bm, r));
    }

    let mut tput = Table::new(
        format!("Serving throughput, {reqs} hot-shape requests (3x{size}x{size}), 1 executor"),
        &["batch_max", "wall ms", "req/s", "speedup", "max batch"],
    );
    let mut hist_t = Table::new(
        "Batch-size histogram (responses per coalescing level)",
        &["batch_max", "batch size", "responses"],
    );
    for (bm, r) in &results {
        let rps = reqs as f64 / (r.wall_ms / 1e3);
        let max_batch = r.hist.keys().max().copied().unwrap_or(1);
        tput.row(vec![
            format!("{bm}"),
            format!("{:.1}", r.wall_ms),
            format!("{rps:.0}"),
            format!("{:.2}x", rps / base_rps),
            format!("{max_batch}"),
        ]);
        for (sz, n) in &r.hist {
            hist_t.row(vec![format!("{bm}"), format!("{sz}"), format!("{n}")]);
        }
    }
    println!("{}", tput.to_text());
    println!("{}", tput.to_json());
    println!("{}", hist_t.to_text());
    println!("{}", hist_t.to_json());

    fairness_leg(size, threads);
    println!("fairness: rare shape served within deadline behind the hot flood");

    let path =
        std::env::var("PHI_SERVING_JSON").unwrap_or_else(|_| "BENCH_serving.json".into());
    if !path.is_empty() {
        let mut obj = BTreeMap::new();
        obj.insert("bench".to_string(), Json::Str("serving".into()));
        obj.insert("hot_shape".to_string(), Json::Str(format!("3x{size}x{size}")));
        obj.insert("requests".to_string(), Json::Num(reqs as f64));
        obj.insert("threads".to_string(), Json::Num(threads as f64));
        obj.insert("unbatched_req_per_s".to_string(), Json::Num(base_rps));
        obj.insert("throughput".to_string(), tput.to_json());
        obj.insert("histogram".to_string(), hist_t.to_json());
        std::fs::write(&path, format!("{}\n", Json::Obj(obj)))
            .unwrap_or_else(|e| panic!("writing {path}: {e}"));
        println!("wrote {path}");
    }
}
