//! Bench: Table 2 — per-image time with the empty-task overhead split,
//! simulated at the paper sizes and measured on this host.
//!
//! `cargo bench --bench overhead` — env overrides:
//!   PHI_BENCH_SIZES=288,576   PHI_BENCH_REPS=5   PHI_BENCH_THREADS=8

const EXHIBIT: &str = "table2";

use phi_conv::config::RunConfig;
use phi_conv::harness;

fn main() {
    let cfg = RunConfig::from_bench_env();
    for t in harness::simulated(EXHIBIT).unwrap() {
        println!("{}", t.to_text());
    }
    for t in harness::run_measured(EXHIBIT, &cfg).unwrap() {
        println!("{}", t.to_text());
    }
}
