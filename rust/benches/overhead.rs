//! Bench: Table 2 — per-image time with the empty-task overhead split,
//! simulated at the paper sizes and measured on this host.
//!
//! `cargo bench --bench overhead` — env overrides:
//!   PHI_BENCH_SIZES=288,576   PHI_BENCH_REPS=5   PHI_BENCH_THREADS=8

const EXHIBIT: &str = "table2";

use phi_conv::config::RunConfig;
use phi_conv::harness;

fn cfg_from_env() -> RunConfig {
    let mut cfg = RunConfig::default();
    if let Ok(s) = std::env::var("PHI_BENCH_SIZES") {
        cfg.sizes = s.split(',').map(|x| x.trim().parse().expect("size")).collect();
    } else {
        cfg.sizes = vec![288, 576]; // keep default bench runtime bounded
    }
    cfg.reps = std::env::var("PHI_BENCH_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
    cfg.warmup = 2;
    if let Ok(t) = std::env::var("PHI_BENCH_THREADS") {
        cfg.threads = t.parse().expect("threads");
    }
    cfg
}

fn main() {
    let cfg = cfg_from_env();
    for t in harness::simulated(EXHIBIT).unwrap() {
        println!("{}", t.to_text());
    }
    for t in harness::run_measured(EXHIBIT, &cfg).unwrap() {
        println!("{}", t.to_text());
    }
}
