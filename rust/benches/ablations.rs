//! Bench: design-choice ablations (DESIGN.md §10) — GPRM cutoff & steal
//! policy, OpenMP schedule, OpenCL local size, measured on this host.
//!
//! `cargo bench --bench ablations` — env overrides as the other benches.

const EXHIBIT: &str = "ablations";

use phi_conv::config::RunConfig;
use phi_conv::harness;

fn cfg_from_env() -> RunConfig {
    let mut cfg = RunConfig::default();
    if let Ok(s) = std::env::var("PHI_BENCH_SIZES") {
        cfg.sizes = s.split(',').map(|x| x.trim().parse().expect("size")).collect();
    } else {
        cfg.sizes = vec![288, 576];
    }
    cfg.reps = std::env::var("PHI_BENCH_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
    cfg.warmup = 2;
    if let Ok(t) = std::env::var("PHI_BENCH_THREADS") {
        cfg.threads = t.parse().expect("threads");
    }
    cfg
}

fn main() {
    let cfg = cfg_from_env();
    for t in harness::run_measured(EXHIBIT, &cfg).unwrap() {
        println!("{}", t.to_text());
    }
}
