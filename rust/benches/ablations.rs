//! Bench: design-choice ablations (DESIGN.md §10) — GPRM cutoff & steal
//! policy, OpenMP schedule, OpenCL local size, measured on this host.
//!
//! `cargo bench --bench ablations` — env overrides as the other benches.

const EXHIBIT: &str = "ablations";

use phi_conv::config::RunConfig;
use phi_conv::harness;

fn main() {
    let cfg = RunConfig::from_bench_env();
    for t in harness::run_measured(EXHIBIT, &cfg).unwrap() {
        println!("{}", t.to_text());
    }
}
