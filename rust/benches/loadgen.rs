//! Bench: the scale-factor load harness — the serving macro-benchmark.
//!
//! Drives the real coordinator with the deterministic default traffic
//! mix (Zipf-skewed shapes, mixed kernel widths, a graph-request
//! fraction, per-request deadlines) at each requested scale factor,
//! under both the open-loop Poisson driver and the closed-loop worker
//! driver, and emits the per-scale SLO curve as `BENCH_load.json` —
//! the macro trajectory file every future perf PR should move.
//!
//! Correctness is asserted, timing is only reported: every issued
//! request must resolve to a structured outcome
//! (served + shed + expired == issued, `failed == 0`) and quoted
//! percentiles must be ordered; p50/p95/p99 themselves are columns to
//! read, not tests to fail (latency asserts would flake on loaded CI
//! runners).
//!
//! `cargo bench --bench loadgen` — env overrides:
//!   PHI_LOAD_SCALE=1,2   PHI_LOAD_MODE=both   PHI_LOAD_EXECUTORS=2
//!   PHI_BENCH_THREADS=8  PHI_LOAD_JSON=BENCH_load.json  (empty = skip)

use phi_conv::config::{default_threads, RunConfig};
use phi_conv::loadgen::{report_table, results_json, run_scales, MixConfig, Mode};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_str(key: &str, default: &str) -> String {
    std::env::var(key).unwrap_or_else(|_| default.to_string())
}

fn main() {
    let scales: Vec<usize> = env_str("PHI_LOAD_SCALE", "1,2")
        .split(',')
        .map(|s| s.trim().parse().expect("PHI_LOAD_SCALE: comma-separated integers"))
        .collect();
    let modes = Mode::parse(&env_str("PHI_LOAD_MODE", "both")).expect("PHI_LOAD_MODE");
    let executors = env_usize("PHI_LOAD_EXECUTORS", 2);
    let threads = env_usize("PHI_BENCH_THREADS", default_threads());

    let cfg = RunConfig {
        threads,
        queue_capacity: 512,
        batch_max: 8,
        ..RunConfig::default()
    };
    // generous deadline: the bench measures the latency distribution;
    // the SLO-violation path is the queue_stress suite's job
    let mix = MixConfig { seed: cfg.seed, deadline_ms: 10_000, ..MixConfig::default() };

    let results =
        run_scales(&cfg, &mix, &scales, &modes, executors, None).expect("load harness run");
    for r in &results {
        assert_eq!(
            r.resolved() as usize,
            r.issued,
            "scale {} {}: every request must resolve",
            r.scale,
            r.mode.label()
        );
        assert_eq!(
            r.failed, 0,
            "scale {} {}: refusals must be structured shed/expired",
            r.scale,
            r.mode.label()
        );
        if let (Some(p50), Some(p95), Some(p99)) =
            (r.hist.percentile(50.0), r.hist.percentile(95.0), r.hist.percentile(99.0))
        {
            assert!(
                p50.is_finite() && p50 <= p95 && p95 <= p99,
                "scale {} {}: percentiles must be finite and ordered",
                r.scale,
                r.mode.label()
            );
        }
    }

    let t = report_table(&results);
    println!("{}", t.to_text());
    println!("{}", t.to_json());

    let path = env_str("PHI_LOAD_JSON", "BENCH_load.json");
    if !path.is_empty() {
        let json = results_json(&mix, &cfg, executors, &results);
        std::fs::write(&path, format!("{json}\n"))
            .unwrap_or_else(|e| panic!("writing {path}: {e}"));
        println!("wrote {path}");
    }
}
