//! Bench: the PJRT request path — artifact compile times and per-request
//! execution latency of the full-image / agglomerated / tile graphs.
//!
//! Not a paper exhibit; this is the §Perf subject for the runtime layer
//! (EXPERIMENTS.md §Perf). `cargo bench --bench runtime_pjrt`.

use phi_conv::image::{synth_image, Pattern};
use phi_conv::metrics::{time_reps, Table};
use phi_conv::runtime::{manifest::default_artifacts_dir, EnginePool};

fn main() {
    let reps: usize =
        std::env::var("PHI_BENCH_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
    let pool = match EnginePool::open(default_artifacts_dir()) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("skipping runtime_pjrt bench: {e}");
            return;
        }
    };
    let k = pool.manifest().kernel_values.clone();

    let mut t = Table::new(
        "PJRT runtime: compile + execute per artifact",
        &["Artifact", "compile ms", "exec p50 ms", "Mpx/s"],
    );
    let entries: Vec<_> = pool
        .manifest()
        .artifacts
        .iter()
        .filter(|a| matches!(a.role.as_str(), "full" | "agg" | "tile"))
        .map(|a| (a.name.clone(), a.inputs[0].shape.clone()))
        .collect();
    for (name, shape) in entries {
        let engine = pool.engine(&name).unwrap();
        let elements: usize = shape.iter().product();
        // synthetic input of the right total element count
        let img = synth_image(1, 1, elements, Pattern::Noise, 42);
        let samples = time_reps(
            || {
                engine.run(&[&img.data, &k]).unwrap();
            },
            2,
            reps,
        );
        let p50 = samples.median();
        t.row(vec![
            name.clone(),
            format!("{:.1}", engine.compile_time_ms),
            format!("{p50:.3}"),
            format!("{:.1}", elements as f64 / p50 / 1e3),
        ]);
    }
    println!("{}", t.to_text());
}
