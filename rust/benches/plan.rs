//! Bench: plan-layer smoke — what does routing through `ConvPlan` cost
//! versus calling the band primitives directly, and what does the
//! width-5 unrolled fast path buy over the generic-width engines?
//!
//! Two tables:
//! 1. plan execute vs a hand-rolled direct dispatch of the same passes
//!    (same buffer loads, same band functions) — the plan overhead;
//! 2. width-5 fast path vs forced-generic at the same width — the
//!    fast-path gain the plan's automatic selection preserves.
//!
//! `cargo bench --bench plan` — env overrides:
//!   PHI_BENCH_SIZES=288,576   PHI_BENCH_REPS=5   PHI_BENCH_THREADS=8

use phi_conv::config::RunConfig;
use phi_conv::conv::{band, Algorithm, Variant};
use phi_conv::image::{synth_image, PlanarImage};
use phi_conv::metrics::{time_reps, Table};
use phi_conv::plan::{ConvPlan, ScratchArena};

/// The two-pass SIMD pipeline written out by hand against the band
/// primitives — the pre-plan dispatch shape, as a baseline.
fn direct_twopass_ms(img: &PlanarImage, k5: &[f32; 5], reps: usize, warmup: usize) -> f64 {
    let (rows, cols) = (img.rows, img.cols);
    let plane_len = rows * cols;
    let mut a = vec![0f32; img.data.len()];
    let mut b = img.data.clone();
    time_reps(
        || {
            a.copy_from_slice(&img.data);
            for p in 0..img.planes {
                let ap = &mut a[p * plane_len..(p + 1) * plane_len];
                let bp = &mut b[p * plane_len..(p + 1) * plane_len];
                band::horiz_band_simd(ap, bp, rows, cols, k5, 0, rows);
                band::vert_band_simd(bp, ap, rows, cols, k5, 0, rows);
            }
        },
        warmup,
        reps,
    )
    .median()
}

fn plan_ms(plan: &ConvPlan, img: &PlanarImage, reps: usize, warmup: usize) -> f64 {
    let mut arena = ScratchArena::new();
    time_reps(|| plan.execute_discard(None, img, &mut arena).unwrap(), warmup, reps).median()
}

fn main() {
    let cfg = RunConfig::from_bench_env();
    let k = phi_conv::image::gaussian_kernel(5, 1.0);
    let k5: &[f32; 5] = k.as_slice().try_into().unwrap();

    let mut t = Table::new(
        "Plan-layer overhead: sequential two-pass SIMD, plan vs direct band dispatch",
        &["Image Size", "direct ms", "plan ms", "overhead"],
    );
    for &size in &cfg.sizes {
        let img = synth_image(cfg.planes, size, size, cfg.pattern, cfg.seed);
        let direct = direct_twopass_ms(&img, k5, cfg.reps, cfg.warmup);
        let plan = ConvPlan::builder()
            .algorithm(Algorithm::TwoPass)
            .variant(Variant::Simd)
            .shape(cfg.planes, size, size)
            .build()
            .unwrap();
        let planned = plan_ms(&plan, &img, cfg.reps, cfg.warmup);
        t.row(vec![
            format!("{size}x{size}"),
            format!("{direct:.3}"),
            format!("{planned:.3}"),
            format!("{:+.1}%", (planned / direct - 1.0) * 100.0),
        ]);
    }
    println!("{}", t.to_text());

    let mut t = Table::new(
        "Width-5 fast path vs generic engines (plan-selected, sequential)",
        &["Image Size", "Variant", "fast ms", "generic ms", "fast-path gain"],
    );
    for &size in &cfg.sizes {
        let img = synth_image(cfg.planes, size, size, cfg.pattern, cfg.seed);
        for (label, variant) in [("no-vec", Variant::Scalar), ("simd", Variant::Simd)] {
            let build = |generic: bool| {
                ConvPlan::builder()
                    .algorithm(Algorithm::TwoPass)
                    .variant(variant)
                    .shape(cfg.planes, size, size)
                    .force_generic(generic)
                    .build()
                    .unwrap()
            };
            let fast = plan_ms(&build(false), &img, cfg.reps, cfg.warmup);
            let generic = plan_ms(&build(true), &img, cfg.reps, cfg.warmup);
            t.row(vec![
                format!("{size}x{size}"),
                label.into(),
                format!("{fast:.3}"),
                format!("{generic:.3}"),
                format!("{:.2}x", generic / fast),
            ]);
        }
    }
    println!("{}", t.to_text());
}
