//! Tier-1 fused-pipeline suite (run standalone by `scripts/verify.sh`).
//!
//! The fused rolling row-ring two-pass must be indistinguishable from
//! the unfused pipeline everywhere it is reachable: a seeded
//! differential sweep across kernel widths {3,5,7,9} × layouts × all
//! three execution models × tiled/untiled dispatch (≤ 1e-6), ring-wrap
//! edge cases (bands shorter than the kernel height, the r0 = 0 prime,
//! the r1 = rows tail), degenerate planes, and the scratch contract:
//! ring leases are O(width×cols) per worker and the arena performs zero
//! allocations after warm-up.
//!
//! Worker counts honour `PHI_THREADS` (the CI scheduling matrix runs
//! this suite at 1 and 4 — the fused leg).

use phi_conv::config::RunConfig;
use phi_conv::conv::band;
use phi_conv::conv::{Algorithm, Variant};
use phi_conv::coordinator::{Backend, ConvRequest, Coordinator, RoutePolicy};
use phi_conv::image::{gaussian_kernel, synth_image, Pattern, PlanarImage};
use phi_conv::models::{
    test_threads, ExecutionModel, GprmModel, Layout, OpenClModel, OpenMpModel,
};
use phi_conv::plan::{ConvPlan, KernelSpec, ScratchArena, TileSpec};

fn threads() -> usize {
    test_threads(4)
}

fn all_models() -> (OpenMpModel, OpenClModel, GprmModel) {
    let t = threads();
    // small OpenCL groups and a 2-D-ish GPRM cutoff so several jobs per
    // worker exercise ring slot recycling
    (OpenMpModel::new(t), OpenClModel::new(t, 4), GprmModel::new(t, 12))
}

fn plan_for(
    width: usize,
    variant: Variant,
    layout: Layout,
    fuse: bool,
    tile: Option<TileSpec>,
    (p, r, c): (usize, usize, usize),
) -> ConvPlan {
    ConvPlan::builder()
        .algorithm(Algorithm::TwoPass)
        .variant(variant)
        .layout(layout)
        .kernel(KernelSpec::new(width, 1.0))
        .fuse(fuse)
        .tile_opt(tile)
        .shape(p, r, c)
        .build()
        .unwrap()
}

fn image() -> PlanarImage {
    synth_image(3, 40, 36, Pattern::Noise, 501)
}

#[test]
fn fused_matches_unfused_across_widths_layouts_models() {
    let img = image();
    let shape = (3, 40, 36);
    let (omp, ocl, gprm) = all_models();
    let models: [&dyn ExecutionModel; 3] = [&omp, &ocl, &gprm];
    let mut arena = ScratchArena::new();
    for width in [3usize, 5, 7, 9] {
        for layout in [Layout::PerPlane, Layout::Agglomerated] {
            for variant in [Variant::Scalar, Variant::Simd] {
                let want = plan_for(width, variant, layout, false, None, shape)
                    .execute(&img, &mut arena)
                    .unwrap();
                let fused = plan_for(width, variant, layout, true, None, shape);
                let seq = fused.execute(&img, &mut arena).unwrap();
                assert!(
                    seq.max_abs_diff(&want) <= 1e-6,
                    "w{width} {layout:?} {variant:?} sequential"
                );
                for model in models {
                    let par = fused.execute_on(model, &img, &mut arena).unwrap();
                    assert!(
                        par.max_abs_diff(&want) <= 1e-6,
                        "w{width} {layout:?} {variant:?} {}",
                        model.name()
                    );
                }
            }
        }
    }
}

#[test]
fn fused_tiled_matches_unfused_untiled() {
    let img = image();
    let shape = (3, 40, 36);
    let (omp, ocl, gprm) = all_models();
    let models: [&dyn ExecutionModel; 3] = [&omp, &ocl, &gprm];
    let mut arena = ScratchArena::new();
    for width in [3usize, 5, 7] {
        for layout in [Layout::PerPlane, Layout::Agglomerated] {
            for variant in [Variant::Scalar, Variant::Simd] {
                let want = plan_for(width, variant, layout, false, None, shape)
                    .execute(&img, &mut arena)
                    .unwrap();
                for tile in [TileSpec::new(7, 9), TileSpec::new(64, 64)] {
                    let fused = plan_for(width, variant, layout, true, Some(tile), shape);
                    let seq = fused.execute(&img, &mut arena).unwrap();
                    assert!(
                        seq.max_abs_diff(&want) <= 1e-6,
                        "w{width} {layout:?} {variant:?} {} seq",
                        tile.label()
                    );
                    for model in models {
                        let par = fused.execute_on(model, &img, &mut arena).unwrap();
                        assert!(
                            par.max_abs_diff(&want) <= 1e-6,
                            "w{width} {layout:?} {variant:?} {} {}",
                            tile.label(),
                            model.name()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn ring_wrap_edge_cases_band_level() {
    // every band primes its own ring: a cover of 1-row bands (all
    // shorter than the kernel height), the r0 = 0 prime and the
    // r1 = rows tail must agree with the whole-plane sweep bitwise
    const R: usize = 17;
    const C: usize = 15;
    let img = synth_image(1, R, C, Pattern::Noise, 77);
    let src = &img.data;
    for width in [3usize, 5, 7, 9] {
        let k = gaussian_kernel(width, 1.0);
        let w = C - 2 * (width / 2);
        let mut full = src.clone();
        let mut ring = vec![0f32; width * w];
        band::fused_band_simd_w(src, &mut full, R, C, &k, &mut ring, 0, R);

        let mut parts = src.clone();
        {
            let mut rest = &mut parts[..];
            for r0 in 0..R {
                let (bandbuf, tail) = rest.split_at_mut(C);
                let mut ring = vec![f32::MAX; width * w]; // prime must overwrite
                band::fused_band_simd_w(src, bandbuf, R, C, &k, &mut ring, r0, r0 + 1);
                rest = tail;
            }
        }
        assert_eq!(full, parts, "w{width}: 1-row bands == full sweep");
    }
}

#[test]
fn fused_with_more_workers_than_rows() {
    // bands degenerate to ≤ 1 row each; ring slots outnumber output
    // rows — the prime/tail logic must hold under every model
    let img = synth_image(2, 9, 30, Pattern::Noise, 13);
    let shape = (2, 9, 30);
    let t = threads().max(8);
    let omp = OpenMpModel::new(t);
    let ocl = OpenClModel::new(t, 1);
    let gprm = GprmModel::new(t, 16);
    let models: [&dyn ExecutionModel; 3] = [&omp, &ocl, &gprm];
    let mut arena = ScratchArena::new();
    let want = plan_for(5, Variant::Simd, Layout::PerPlane, false, None, shape)
        .execute(&img, &mut arena)
        .unwrap();
    let fused = plan_for(5, Variant::Simd, Layout::PerPlane, true, None, shape);
    for model in models {
        let got = fused.execute_on(model, &img, &mut arena).unwrap();
        assert!(got.max_abs_diff(&want) <= 1e-6, "{}", model.name());
    }
}

#[test]
fn degenerate_planes_pass_through_fused() {
    // rows < kernel height, 1×N and N×1 planes: the fused plan returns
    // the input unchanged, never panics
    let mut arena = ScratchArena::new();
    for (rows, cols) in [(1usize, 1usize), (1, 8), (8, 1), (3, 8), (8, 3), (4, 4)] {
        let img = synth_image(2, rows, cols, Pattern::Noise, 3);
        for variant in [Variant::Scalar, Variant::Simd] {
            let plan = plan_for(5, variant, Layout::PerPlane, true, None, (2, rows, cols));
            let out = plan.execute(&img, &mut arena).unwrap();
            assert_eq!(out, img, "{rows}x{cols} {variant:?}");
        }
        // width 7 (kernel taller/wider than every shape here), tiled fused
        let tile = Some(TileSpec::new(2, 2));
        let plan = plan_for(7, Variant::Simd, Layout::PerPlane, true, tile, (2, rows, cols));
        let out = plan.execute(&img, &mut arena).unwrap();
        assert_eq!(out, img, "{rows}x{cols} tiled w7");
    }
}

#[test]
fn ring_leases_are_width_by_cols_and_never_grow_the_arena() {
    let shape = (3, 48, 44);
    let img = synth_image(3, 48, 44, Pattern::Noise, 99);

    // the acceptance assertion: fused scratch is O(width × cols) per
    // worker, exposed through the plan's ring footprint
    for width in [3usize, 5, 7, 9] {
        let plan = plan_for(width, Variant::Simd, Layout::PerPlane, true, None, shape);
        assert_eq!(plan.ring_footprint(), width * (44 - 2 * (width / 2)), "w{width}");
    }
    // tiled rings clamp to the tile width; agglomerated spans the wide plane
    let tile = Some(TileSpec::new(8, 12));
    let plan = plan_for(5, Variant::Simd, Layout::PerPlane, true, tile, shape);
    assert_eq!(plan.ring_footprint(), 5 * 12);
    let plan = plan_for(5, Variant::Simd, Layout::Agglomerated, true, None, shape);
    assert_eq!(plan.ring_footprint(), 5 * (3 * 44 - 4));
    // unfused plans have no ring at all
    let plan = plan_for(5, Variant::Simd, Layout::PerPlane, false, None, shape);
    assert_eq!(plan.ring_footprint(), 0);

    // arena no-growth: rings recycle like the A/B planes
    let (omp, _, gprm) = all_models();
    for model in [&omp as &dyn ExecutionModel, &gprm] {
        let mut arena = ScratchArena::new();
        let fused = plan_for(5, Variant::Simd, Layout::PerPlane, true, None, shape);
        fused.execute_on(model, &img, &mut arena).unwrap();
        let warm = arena.allocations();
        for _ in 0..8 {
            fused.execute_on(model, &img, &mut arena).unwrap();
        }
        assert_eq!(arena.allocations(), warm, "{}: fused steady state allocates", model.name());
    }
}

#[test]
fn coordinator_serves_fused_traffic() {
    let cfg = RunConfig { threads: threads(), fuse: true, ..Default::default() };
    let c = Coordinator::new(&cfg, RoutePolicy::RoundRobin, 2, false).unwrap();
    let img = synth_image(3, 32, 30, Pattern::Noise, 55);
    let k = gaussian_kernel(5, 1.0);
    let want =
        phi_conv::conv::convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
    // fused default across the backend rotation
    for i in 0..6u64 {
        let resp = c.serve(ConvRequest::new(i, img.clone())).unwrap();
        assert!(resp.image.max_abs_diff(&want) <= 1e-6, "request {i} via {:?}", resp.backend);
    }
    // per-request opt-out and explicit opt-in coexist in the plan cache
    let off = c.serve(ConvRequest::new(10, img.clone()).with_fuse(false)).unwrap();
    assert!(off.image.max_abs_diff(&want) <= 1e-6);
    let on = c
        .serve(ConvRequest::new(11, img.clone()).with_fuse(true).with_backend(Backend::NativeGprm))
        .unwrap();
    assert!(on.image.max_abs_diff(&want) <= 1e-6);
    // single-pass requests are served (fusion silently inapplicable)
    let sp = c
        .serve(ConvRequest::new(12, img).with_algorithm(Algorithm::SinglePassNoCopy))
        .unwrap();
    assert!(sp.service_ms >= 0.0);
    let st = c.stats();
    assert_eq!(st.errors, 0);
    assert_eq!(st.served, 9);
}

#[test]
fn fused_plans_reject_single_pass_algorithms() {
    for alg in [Algorithm::SinglePassCopyBack, Algorithm::SinglePassNoCopy] {
        let e = ConvPlan::builder().algorithm(alg).fuse(true).shape(1, 16, 16).build();
        assert!(e.is_err(), "{alg:?}");
    }
}
