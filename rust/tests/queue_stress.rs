//! Queue stress suite (tier-1, wired into scripts/verify.sh): the
//! coordinator's bounded admission path under the loads that used to
//! panic or hang it —
//!
//! * a burst far beyond capacity (shed with structured `QueueFull`,
//!   never OOM or panic),
//! * shutdown while the queue is still draining (every outstanding
//!   reply resolves to a response or a structured `Shutdown` /
//!   `DeadlineExceeded` error — never a hung `recv`),
//! * deadlines lapsing while jobs wait behind a busy executor,
//! * plan-keyed batching fairness: a rare shape behind a hot-shape
//!   flood still serves within its deadline, and batched responses are
//!   bitwise-equal to the same requests served singly.

use std::time::Duration;

use phi_conv::config::RunConfig;
use phi_conv::coordinator::{Backend, ConvRequest, Coordinator, RoutePolicy};
use phi_conv::image::{synth_image, Pattern, PlanarImage};
use phi_conv::loadgen::{run_mode, MixConfig, Mode, RequestPlan};
use phi_conv::ErrorKind;

fn cfg(queue_capacity: usize) -> RunConfig {
    RunConfig { threads: 2, queue_capacity, ..Default::default() }
}

/// Big enough that one convolution takes real time (the executor stays
/// busy while the test floods the queue), small enough to stay fast.
fn busy_image(seed: u64) -> PlanarImage {
    synth_image(3, 160, 160, Pattern::Noise, seed)
}

#[test]
fn burst_beyond_capacity_sheds_never_panics() {
    let coord = Coordinator::new(&cfg(2), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false)
        .unwrap();
    // requests pre-built so the burst loop is tight: the executor can
    // serve at most a couple while 64 try_submits hammer a capacity-2
    // queue, so shedding is guaranteed
    let reqs: Vec<_> = (0..64u64).map(|i| ConvRequest::new(i, busy_image(i))).collect();
    let mut admitted = Vec::new();
    let mut shed = 0u64;
    for req in reqs {
        match coord.try_submit(req) {
            Ok(rx) => admitted.push(rx),
            Err(e) => {
                assert_eq!(e.kind(), ErrorKind::QueueFull, "got: {e:#}");
                shed += 1;
            }
        }
    }
    assert!(shed >= 1, "64-burst into capacity 2 must shed");
    for rx in admitted {
        let resp = rx.recv().expect("reply must arrive").expect("admitted request serves");
        assert!(resp.service_ms >= 0.0);
    }
    let st = coord.stats();
    assert_eq!(st.shed, shed);
    assert_eq!(st.served + st.shed, 64);
    assert_eq!(st.errors, 0);
    assert!(st.depth_peak >= 1 && st.depth_peak <= 2);
}

#[test]
fn shutdown_under_load_resolves_every_reply() {
    // enqueue more jobs than capacity, drop the coordinator mid-drain:
    // every reply channel must resolve — to a response or a structured
    // Shutdown/DeadlineExceeded error — and never hang or panic
    let coord = Coordinator::new(&cfg(8), RoutePolicy::Fixed(Backend::NativeOpenMp), 2, false)
        .unwrap();
    let mut receivers = Vec::new();
    let mut pre_shed = 0usize;
    for i in 0..40u64 {
        // half the traffic carries a tight TTL so the drain also
        // exercises the queued-but-expired rejection path
        let mut req = ConvRequest::new(i, busy_image(100 + i));
        if i % 2 == 0 {
            req = req.with_deadline(Duration::from_millis(1));
        }
        match coord.try_submit(req) {
            Ok(rx) => receivers.push(rx),
            Err(e) => {
                assert!(
                    matches!(e.kind(), ErrorKind::QueueFull | ErrorKind::DeadlineExceeded),
                    "pre-drop refusals are structured: {e:#}"
                );
                pre_shed += 1;
            }
        }
    }
    assert!(!receivers.is_empty(), "some requests must have been admitted");

    drop(coord); // graceful drain: close intake, finish what's queued

    let mut ok = 0usize;
    let mut structured = 0usize;
    for rx in receivers {
        // the drain already completed (drop joins the executors), so
        // replies are immediate; recv_timeout guards against the old
        // hang-forever failure mode turning into a stuck test
        match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(Ok(resp)) => {
                assert!(resp.service_ms >= 0.0);
                ok += 1;
            }
            Ok(Err(e)) => {
                assert!(
                    matches!(e.kind(), ErrorKind::Shutdown | ErrorKind::DeadlineExceeded),
                    "refusal must be structured, got: {e:#}"
                );
                structured += 1;
            }
            Err(_) => panic!("reply channel hung or dangled after shutdown"),
        }
    }
    assert_eq!(ok + structured + pre_shed, 40, "every request accounted for");
}

#[test]
fn deadlines_lapse_behind_a_busy_executor() {
    // one executor, work queued behind a slow job with a TTL shorter
    // than the blocker: whatever isn't served in time must come back
    // as DeadlineExceeded (checked at dequeue), the rest serve fine
    let coord = Coordinator::new(&cfg(32), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false)
        .unwrap();
    let blocker = coord.submit(ConvRequest::new(0, busy_image(7))).unwrap();
    let mut rxs = Vec::new();
    for i in 1..=8u64 {
        let req = ConvRequest::new(i, busy_image(7)).with_deadline(Duration::from_nanos(1));
        match coord.submit(req) {
            Ok(rx) => rxs.push(rx),
            // admission may already classify the lapse — also correct
            Err(e) => assert_eq!(e.kind(), ErrorKind::DeadlineExceeded, "got: {e:#}"),
        }
    }
    assert!(blocker.recv().unwrap().is_ok(), "the blocker itself has no deadline");
    for rx in rxs {
        let reply = rx.recv().expect("reply must arrive");
        let e = reply.expect_err("1 ns TTL cannot be served behind a blocker");
        assert_eq!(e.kind(), ErrorKind::DeadlineExceeded, "got: {e:#}");
    }
    let st = coord.stats();
    assert_eq!(st.expired, 8);
    assert_eq!(st.served, 1);
}

#[test]
fn rare_shape_behind_hot_flood_is_served_within_deadline() {
    // batching fairness: coalescing removes only PlanKey-matching jobs
    // from the queue, so a minority shape buried in a flood of hot
    // traffic keeps its FIFO position and is served within its deadline
    // — the hot batches must not starve it
    let cfg = RunConfig { batch_max: 8, ..cfg(64) };
    let coord =
        Coordinator::new(&cfg, RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
    let hot = synth_image(3, 96, 96, Pattern::Noise, 11);
    let mut hot_rxs = Vec::new();
    for i in 0..24u64 {
        hot_rxs.push(coord.submit(ConvRequest::new(i, hot.clone())).unwrap());
    }
    let rare = synth_image(3, 80, 72, Pattern::Noise, 12);
    let rare_rx = coord
        .submit(ConvRequest::new(99, rare).with_deadline(Duration::from_secs(30)))
        .unwrap();
    let resp = rare_rx
        .recv()
        .expect("reply must arrive")
        .expect("rare shape must be served, not starved past its deadline");
    assert_eq!(resp.id, 99);
    for rx in hot_rxs {
        assert!(rx.recv().unwrap().is_ok(), "hot traffic serves too");
    }
    let st = coord.stats();
    assert_eq!(st.served, 25);
    assert_eq!(st.expired, 0, "nothing may lapse in this mix");
}

#[test]
fn batched_responses_bitwise_equal_singly_served() {
    // the acceptance bar for coalescing: a batch member's pixels are
    // indistinguishable from the same request served alone
    let imgs: Vec<PlanarImage> =
        (0..6u64).map(|s| synth_image(3, 64, 64, Pattern::Noise, 40 + s)).collect();

    // baseline: default batch_max = 1 serves each request singly
    let single =
        Coordinator::new(&cfg(32), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false)
            .unwrap();
    let wants: Vec<PlanarImage> = imgs
        .iter()
        .enumerate()
        .map(|(i, img)| single.serve(ConvRequest::new(i as u64, img.clone())).unwrap().image)
        .collect();

    // batched: a big blocker pins the executor while the six same-key
    // requests queue up, so they coalesce when it comes free
    let cfg = RunConfig { batch_max: 8, ..cfg(32) };
    let batched =
        Coordinator::new(&cfg, RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
    let blocker =
        batched.submit(ConvRequest::new(100, synth_image(3, 512, 512, Pattern::Noise, 9))).unwrap();
    let rxs: Vec<_> = imgs
        .iter()
        .enumerate()
        .map(|(i, img)| batched.submit(ConvRequest::new(i as u64, img.clone())).unwrap())
        .collect();
    assert!(blocker.recv().unwrap().is_ok());
    let mut max_batch = 0usize;
    for (rx, want) in rxs.into_iter().zip(&wants) {
        let resp = rx.recv().expect("reply").expect("batch member serves");
        assert_eq!(resp.image, *want, "batched output must be bitwise-equal");
        max_batch = max_batch.max(resp.batch_len);
    }
    assert!(max_batch >= 2, "the six queued same-key jobs must coalesce, got {max_batch}");
}

#[test]
fn load_mix_slo_violations_are_structured_shed_and_expiry() {
    // the loadgen overload leg: a realistic mixed-traffic plan (hot
    // shapes, mixed widths, a graph fraction) fired effectively all at
    // once into an undersized queue with deadlines far below the
    // service time. Admission must shed (QueueFull), whatever queues
    // behind the busy executor must expire (DeadlineExceeded), and
    // nothing may land in the unstructured `failed` bucket — the
    // accounting identity holds even when almost everything is refused.
    let mix = MixConfig {
        min_size: 192,
        max_size: 224,
        deadline_ms: 1,
        requests_per_scale: 64,
        rate_per_s: 1e6,
        ..MixConfig::default()
    };
    let plan = RequestPlan::generate(&mix, 3).unwrap();
    let r = run_mode(&cfg(2), &plan, Mode::Open, 1, None).unwrap();
    assert_eq!(r.issued, 192);
    assert_eq!(
        r.resolved() as usize,
        r.issued,
        "overload must not lose requests: served {} shed {} expired {} failed {}",
        r.served,
        r.shed,
        r.expired,
        r.failed
    );
    assert_eq!(r.failed, 0, "every refusal must carry a structured kind");
    assert!(r.shed > 0, "192 near-instant arrivals into capacity 2 must shed");
    assert!(
        r.expired > 0,
        "a 1 ms TTL behind a 192x224-class convolution must lapse in the queue"
    );
    // coordinator counters saw the same story
    assert_eq!(r.stats.shed, r.shed);
    assert_eq!(r.stats.expired, r.expired);
    assert!(r.stats.depth_peak <= 2, "capacity 2 bounds the queue");
}

#[test]
fn load_plan_drain_after_drop_resolves_every_reply() {
    // submit a whole realized plan, then drop the coordinator while
    // replies are outstanding: the drain must resolve every admitted
    // reply to a response or a structured kind — never a hang (the
    // recv_timeout below converts the old hang-forever failure mode
    // into a loud test failure)
    let mix = MixConfig {
        min_size: 128,
        max_size: 160,
        deadline_ms: 5,
        requests_per_scale: 32,
        rate_per_s: 1e6,
        ..MixConfig::default()
    };
    let plan = RequestPlan::generate(&mix, 1).unwrap();
    let coord = Coordinator::new(&cfg(4), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false)
        .unwrap();
    let mut pending = Vec::new();
    let mut refused = 0usize;
    for req in plan.realize(Pattern::Noise) {
        match coord.try_submit(req) {
            Ok(rx) => pending.push(rx),
            Err(e) => {
                assert!(
                    matches!(e.kind(), ErrorKind::QueueFull | ErrorKind::DeadlineExceeded),
                    "admission refusals are structured: {e:#}"
                );
                refused += 1;
            }
        }
    }
    drop(coord);
    let mut resolved = 0usize;
    for rx in pending {
        match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(Ok(resp)) => {
                assert!(resp.service_ms >= 0.0);
                resolved += 1;
            }
            Ok(Err(e)) => {
                assert!(
                    matches!(
                        e.kind(),
                        ErrorKind::Shutdown | ErrorKind::DeadlineExceeded | ErrorKind::QueueFull
                    ),
                    "drain refusals are structured: {e:#}"
                );
                resolved += 1;
            }
            Err(_) => panic!("reply channel hung or dangled after shutdown"),
        }
    }
    assert_eq!(resolved + refused, plan.issued(), "every planned request accounted for");
}

#[test]
fn submit_timeout_bounds_the_wait() {
    // capacity 1 + one executor pinned on a large job, queue already
    // holding a second: a bounded blocking submit must give up with
    // QueueFull after ~its timeout instead of blocking forever
    let coord = Coordinator::new(&cfg(1), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false)
        .unwrap();
    // 768² x 3 two-pass is far slower than the 1 ms timeout below, so
    // the slot cannot free while the bounded submit waits
    let huge = synth_image(3, 768, 768, Pattern::Noise, 3);
    let b1 = coord.submit(ConvRequest::new(0, huge.clone())).unwrap(); // executing
    let b2 = coord.submit(ConvRequest::new(1, huge)).unwrap(); // fills capacity 1
    let t0 = std::time::Instant::now();
    let e = coord
        .submit_timeout(ConvRequest::new(2, busy_image(1)), Duration::from_millis(1))
        .expect_err("queue is full behind two large blockers");
    assert_eq!(e.kind(), ErrorKind::QueueFull, "got: {e:#}");
    assert!(t0.elapsed() >= Duration::from_millis(1), "must have actually waited");
    assert!(b1.recv().unwrap().is_ok());
    assert!(b2.recv().unwrap().is_ok());
    let st = coord.stats();
    assert_eq!((st.shed, st.served), (1, 2));
}
