//! Tier-1 FilterGraph suite (run standalone by `scripts/verify.sh`).
//!
//! The streamed multi-stage cascade must be indistinguishable from
//! running the same stages one materialised plan at a time: a
//! differential sweep over 2/3/4-stage chains × both layouts × all
//! three execution models (≤ 1e-6 everywhere; bitwise for the
//! generic-width PerPlane chains where the engines share every
//! accumulation expression), fan-out demotion semantics, the
//! graph-scoped scratch contract (ring leases recycle, zero arena
//! allocations after warm-up), and end-to-end coordinator serving of
//! graph requests with the `graphs_served`/`stages_fused` counters.
//!
//! Worker counts honour `PHI_THREADS` like the other tier-1 suites.

use phi_conv::config::RunConfig;
use phi_conv::conv::Variant;
use phi_conv::coordinator::{ConvRequest, Coordinator, GraphSpec, RoutePolicy};
use phi_conv::image::{synth_image, Pattern};
use phi_conv::models::{
    test_threads, ExecutionModel, GprmModel, Layout, OpenClModel, OpenMpModel,
};
use phi_conv::plan::{EdgePolicy, FilterGraph, KernelSpec, ScratchArena};

fn threads() -> usize {
    test_threads(4)
}

fn chain_widths(n: usize) -> &'static [usize] {
    match n {
        2 => &[3, 7],
        3 => &[3, 7, 9],
        _ => &[3, 5, 7, 9],
    }
}

fn build_chain(n: usize, planes: usize, rows: usize, cols: usize, layout: Layout) -> FilterGraph {
    let mut b = FilterGraph::builder().shape(planes, rows, cols).layout(layout);
    for (i, &w) in chain_widths(n).iter().enumerate() {
        b = b.stage(&format!("s{i}"), KernelSpec::new(w, 0.4 + w as f64 / 4.0));
    }
    b.build().unwrap()
}

#[test]
fn streamed_chains_match_materialized_across_models_and_layouts() {
    let (p, r, c) = (2usize, 44usize, 38usize);
    let img = synth_image(p, r, c, Pattern::Noise, 901);
    let t = threads();
    let omp = OpenMpModel::new(t);
    let ocl = OpenClModel::new(t, 4);
    let gprm = GprmModel::new(t, 12);
    let models: [&dyn ExecutionModel; 3] = [&omp, &ocl, &gprm];
    let mut arena = ScratchArena::new();
    for n in [2usize, 3, 4] {
        for layout in [Layout::PerPlane, Layout::Agglomerated] {
            let g = build_chain(n, p, r, c, layout);
            assert_eq!(g.streamed_edges(), n - 1, "{n} stages: linear chain streams fully");
            let want = g.execute_materialized(None, &img, &mut arena).unwrap();
            let seq = g.execute(&img, &mut arena).unwrap();
            assert_eq!(seq.len(), 1);
            let d = seq[0].max_abs_diff(&want[0]);
            assert!(d <= 1e-6, "{n} stages {layout:?} seq vs oracle: {d}");
            // generic widths share every accumulation expression with
            // the fused plan engines; width 5 takes the plan's unrolled
            // fast path, so only the ≤1e-6 bound is claimed there
            if layout == Layout::PerPlane && !chain_widths(n).contains(&5) {
                assert_eq!(seq[0].data, want[0].data, "{n} stages: generic chain is bitwise");
            }
            for model in models {
                let par = g.execute_on(model, &img, &mut arena).unwrap();
                assert_eq!(
                    par[0].data,
                    seq[0].data,
                    "{n} stages {layout:?} {}: banded != sequential",
                    model.name()
                );
            }
        }
    }
}

#[test]
fn fan_out_graph_demotes_and_serves_both_outputs() {
    // difference-of-Gaussians shape: the narrow blur feeds the wide one
    // while being a graph output itself, so its outgoing edge must
    // demote to materialised and both outputs must match the per-plan
    // oracle bitwise (generic widths, PerPlane)
    let (p, r, c) = (1usize, 30usize, 28usize);
    let img = synth_image(p, r, c, Pattern::Noise, 5);
    let g = FilterGraph::builder()
        .shape(p, r, c)
        .stage("narrow", KernelSpec::new(3, 0.8))
        .stage("wide", KernelSpec::new(7, 1.4))
        .output("narrow")
        .output("wide")
        .build()
        .unwrap();
    assert_eq!(g.streamed_edges(), 0, "consumed-output edge must demote");
    assert_eq!(g.stages()[1].policy(), EdgePolicy::Materialized);
    assert_eq!(g.output_names(), ["narrow", "wide"]);
    let mut arena = ScratchArena::new();
    let outs = g.execute(&img, &mut arena).unwrap();
    let want = g.execute_materialized(None, &img, &mut arena).unwrap();
    assert_eq!(outs.len(), 2);
    for (i, (a, b)) in outs.iter().zip(&want).enumerate() {
        assert_eq!(a.data, b.data, "output {i} must match the oracle bitwise");
    }
    let omp = OpenMpModel::new(threads());
    let par = g.execute_on(&omp, &img, &mut arena).unwrap();
    for (i, (a, b)) in par.iter().zip(&outs).enumerate() {
        assert_eq!(a.data, b.data, "output {i}: banded != sequential");
    }
}

#[test]
fn graph_footprint_halo_and_traffic_accounting() {
    let (p, r, c) = (1usize, 40usize, 36usize);
    for n in [2usize, 3, 4] {
        let g = build_chain(n, p, r, c, Layout::PerPlane);
        let halo: usize = chain_widths(n).iter().map(|w| w / 2).sum();
        assert_eq!(g.accumulated_halo(), halo, "{n} stages");
        assert!(g.ring_footprint() > 0, "{n} stages: streamed chain needs a ring");
        let t = g.traffic_estimate();
        assert!(
            t.total.total_mb() < t.materialized_total.total_mb(),
            "{n} stages: streaming must reduce estimated traffic"
        );
        // --explain: one row per stage plus the totals row
        assert_eq!(g.explain().n_rows(), n + 1, "{n} stages");
    }
}

#[test]
fn graph_execution_recycles_arena_after_warmup() {
    let (p, r, c) = (2usize, 40usize, 36usize);
    let img = synth_image(p, r, c, Pattern::Noise, 71);
    let omp = OpenMpModel::new(threads());
    for layout in [Layout::PerPlane, Layout::Agglomerated] {
        let g = build_chain(3, p, r, c, layout);
        let mut arena = ScratchArena::new();
        g.execute(&img, &mut arena).unwrap();
        g.execute_on(&omp, &img, &mut arena).unwrap();
        let warm = arena.allocations();
        for _ in 0..8 {
            g.execute(&img, &mut arena).unwrap();
            g.execute_on(&omp, &img, &mut arena).unwrap();
        }
        assert_eq!(arena.allocations(), warm, "{layout:?}: graph steady state allocates");
    }
}

#[test]
fn coordinator_serves_graph_chains_across_backends() {
    let cfg = RunConfig { threads: threads(), ..Default::default() };
    let c = Coordinator::new(&cfg, RoutePolicy::RoundRobin, 2, false).unwrap();
    let img = synth_image(2, 36, 32, Pattern::Noise, 31);
    let spec = GraphSpec::chain(vec![KernelSpec::new(3, 0.8), KernelSpec::new(7, 1.5)]);
    let mut arena = ScratchArena::new();
    let want = spec
        .build(2, 36, 32, Variant::Simd, Layout::PerPlane)
        .unwrap()
        .execute_materialized(None, &img, &mut arena)
        .unwrap()
        .pop()
        .unwrap();
    // streamed chains across the native backend rotation
    for i in 0..6u64 {
        let req = ConvRequest::new(i, img.clone())
            .with_layout(Layout::PerPlane)
            .with_graph(spec.clone());
        let resp = c.serve(req).unwrap();
        assert!(
            resp.image.max_abs_diff(&want) <= 1e-6,
            "request {i} via {:?}",
            resp.backend
        );
    }
    // a materialised-policy chain serves through the same path
    let req = ConvRequest::new(9, img.clone())
        .with_layout(Layout::PerPlane)
        .with_graph(spec.clone().materialized());
    let resp = c.serve(req).unwrap();
    assert!(resp.image.max_abs_diff(&want) <= 1e-6);
    let st = c.stats();
    assert_eq!(st.errors, 0);
    assert_eq!(st.served, 7);
    assert_eq!(st.graphs_served, 7);
    assert_eq!(st.stages_fused, 6, "6 streamed requests x 1 streamed edge");
}

#[test]
fn coordinator_rejects_malformed_graph_requests() {
    let cfg = RunConfig { threads: threads(), ..Default::default() };
    let c = Coordinator::new(&cfg, RoutePolicy::RoundRobin, 1, false).unwrap();
    let img = synth_image(1, 24, 24, Pattern::Noise, 3);
    // even-width stage: a structured error, not a panic, and no
    // graphs_served credit
    let bad = GraphSpec::chain(vec![KernelSpec::new(4, 1.0)]);
    let e = c.serve(ConvRequest::new(1, img.clone()).with_graph(bad)).unwrap_err();
    assert!(format!("{e:#}").contains("invalid request graph"), "{e:#}");
    // a good request still serves afterwards
    let good = GraphSpec::chain(vec![KernelSpec::new(3, 0.8)]);
    c.serve(ConvRequest::new(2, img).with_graph(good)).unwrap();
    let st = c.stats();
    assert_eq!(st.errors, 1);
    assert_eq!(st.graphs_served, 1);
}
