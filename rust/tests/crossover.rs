//! Integration: cross-class differentials through the public plan API.
//!
//! The kernel-class planner adds two execution paths next to the
//! separable two-pass engines — banded direct 2-D and the radix-2 FFT
//! convolver — and the repo's rule is that every new path is
//! differential-tested against an existing one. Here:
//!
//! * FFT ≡ direct 2-D within 1e-4 for random non-separable kernels,
//!   across layouts and all three execution models;
//! * direct 2-D on a separable (Gaussian) kernel ≡ the separable
//!   two-pass engines within 1e-6, tiled and untiled;
//! * the FFT scratch leases reach allocation steady state (the arena
//!   no-growth invariant extends to the new f64 lease types).

use phi_conv::image::{synth_image, Pattern};
use phi_conv::models::{ExecutionModel, GprmModel, Layout, OpenClModel, OpenMpModel, TileSpec};
use phi_conv::plan::{ConvPlan, Kernel2d, KernelClass, KernelSpec, ScratchArena};
use phi_conv::util::prng::Prng;

fn models() -> Vec<Box<dyn ExecutionModel>> {
    vec![
        Box::new(OpenMpModel::new(3)),
        Box::new(OpenClModel::new(2, 4)),
        Box::new(GprmModel::new(3, 12)),
    ]
}

/// A random kernel normalised to Σ|t| = 1 (keeps outputs O(input), so
/// absolute tolerances stay meaningful). Random taps are effectively
/// never rank-1, so these exercise the genuinely non-separable path.
fn random_kernel2d(rng: &mut Prng, krows: usize, kcols: usize) -> Kernel2d {
    let mut taps: Vec<f32> = (0..krows * kcols).map(|_| rng.f32() - 0.5).collect();
    let norm: f32 = taps.iter().map(|v| v.abs()).sum::<f32>().max(1e-6);
    for v in &mut taps {
        *v /= norm;
    }
    Kernel2d::new(taps, krows, kcols).unwrap()
}

#[test]
fn fft_matches_direct2d_for_random_kernels_across_models_and_layouts() {
    let mut rng = Prng::new(0xFF7_C0DE);
    let odd = [3usize, 5, 7];
    for case in 0..8 {
        let krows = *rng.pick(&odd);
        let kcols = *rng.pick(&odd);
        let k = random_kernel2d(&mut rng, krows, kcols);
        let planes = rng.range(1, 3);
        let rows = rng.range(24, 40);
        let cols = rng.range(24, 40);
        let img = synth_image(planes, rows, cols, Pattern::Noise, 0xA11 + case as u64);
        let mut arena = ScratchArena::new();

        for layout in [Layout::PerPlane, Layout::Agglomerated] {
            // the reference is per layout: agglomerated planes sit side
            // by side and legitimately read across plane seams, so its
            // pixels differ from PerPlane near seam columns
            let direct = ConvPlan::builder()
                .layout(layout)
                .kernel2d(k.clone())
                .kernel_class(KernelClass::Direct2d)
                .shape(planes, rows, cols)
                .build()
                .unwrap();
            let base = direct.execute(&img, &mut arena).unwrap();

            for model in models() {
                for tile in [None, Some(TileSpec::new(8, usize::MAX))] {
                    let plan = ConvPlan::builder()
                        .layout(layout)
                        .kernel2d(k.clone())
                        .kernel_class(KernelClass::Direct2d)
                        .tile_opt(tile)
                        .shape(planes, rows, cols)
                        .build()
                        .unwrap();
                    let got = plan.execute_on(model.as_ref(), &img, &mut arena).unwrap();
                    let d = got.max_abs_diff(&base);
                    assert!(
                        d < 1e-5,
                        "case {case}: direct2d {} {layout:?} tiled={} diverged by {d:e} \
                         ({krows}x{kcols} kernel, {planes}x{rows}x{cols})",
                        model.name(),
                        tile.is_some()
                    );
                }
                let fft = ConvPlan::builder()
                    .layout(layout)
                    .kernel2d(k.clone())
                    .kernel_class(KernelClass::Fft)
                    .shape(planes, rows, cols)
                    .build()
                    .unwrap();
                let got = fft.execute_on(model.as_ref(), &img, &mut arena).unwrap();
                let d = got.max_abs_diff(&base);
                assert!(
                    d < 1e-4,
                    "case {case}: fft {} {layout:?} diverged by {d:e} from direct2d \
                     ({krows}x{kcols} kernel, {planes}x{rows}x{cols})",
                    model.name()
                );
            }
        }
    }
}

#[test]
fn direct2d_on_separable_kernels_matches_the_two_pass_engines() {
    let mut rng = Prng::new(0x5E9A_12B1);
    for case in 0..8 {
        let width = *rng.pick(&[3usize, 5, 9]);
        let planes = rng.range(1, 3);
        let rows = rng.range(20, 40);
        let cols = rng.range(20, 40);
        let spec = KernelSpec::new(width, (width as f64 / 5.0).max(0.5));
        let img = synth_image(planes, rows, cols, Pattern::Gradient, 7 + case as u64);
        let mut arena = ScratchArena::new();

        let sep = ConvPlan::builder()
            .kernel(spec)
            .shape(planes, rows, cols)
            .build()
            .unwrap();
        assert_eq!(sep.class(), KernelClass::Separable, "Gaussian specs stay separable");
        let want = sep.execute(&img, &mut arena).unwrap();

        for model in models() {
            for tile in [None, Some(TileSpec::new(8, usize::MAX))] {
                let direct = ConvPlan::builder()
                    .kernel(spec)
                    .kernel_class(KernelClass::Direct2d)
                    .tile_opt(tile)
                    .shape(planes, rows, cols)
                    .build()
                    .unwrap();
                assert_eq!(direct.class(), KernelClass::Direct2d);
                let got = direct.execute_on(model.as_ref(), &img, &mut arena).unwrap();
                let d = got.max_abs_diff(&want);
                assert!(
                    d < 1e-6,
                    "case {case}: direct2d({}) tiled={} vs two-pass diff {d:e} \
                     (w{width}, {planes}x{rows}x{cols})",
                    model.name(),
                    tile.is_some()
                );
            }
        }
    }
}

#[test]
fn fft_scratch_reaches_allocation_steady_state() {
    let img = synth_image(2, 40, 36, Pattern::Noise, 99);
    let mut arena = ScratchArena::new();
    let plan = ConvPlan::builder()
        .kernel(KernelSpec::new(9, 1.8))
        .kernel_class(KernelClass::Fft)
        .shape(2, 40, 36)
        .build()
        .unwrap();
    let warm = plan.execute(&img, &mut arena).unwrap();
    let allocs = arena.allocations();
    assert!(allocs > 0, "the FFT path leases scratch through the arena");
    for _ in 0..10 {
        let again = plan.execute(&img, &mut arena).unwrap();
        assert_eq!(again.data.len(), warm.data.len());
    }
    assert_eq!(
        arena.allocations(),
        allocs,
        "steady-state FFT execution must recycle every lease, not allocate"
    );
    assert!(arena.pooled() > 0, "leases return to the pool between runs");
}
