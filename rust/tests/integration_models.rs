//! Integration: the full (model × algorithm × variant × layout) matrix
//! produces pixels identical to the sequential engines, across awkward
//! shapes, thread counts, cutoffs and local sizes.

use phi_conv::conv::{convolve_image, Algorithm, Variant};
use phi_conv::image::{gaussian_kernel, synth_image, Pattern};
use phi_conv::models::{
    convolve_parallel, ExecutionModel, GprmModel, Layout, OpenClModel, OpenMpModel,
};

fn k5() -> Vec<f32> {
    gaussian_kernel(5, 1.0)
}

#[test]
fn full_matrix_odd_shape() {
    // 37x53 defeats every divisibility assumption
    let img = synth_image(3, 37, 53, Pattern::Noise, 1);
    let k = k5();
    let models: Vec<Box<dyn ExecutionModel>> = vec![
        Box::new(OpenMpModel::new(5)),
        Box::new(OpenClModel::new(3, 7)),
        Box::new(GprmModel::new(4, 11)),
    ];
    for alg in [Algorithm::TwoPass, Algorithm::SinglePassCopyBack, Algorithm::SinglePassNoCopy] {
        for variant in [Variant::Scalar, Variant::Simd] {
            let want = convolve_image(img.clone(), &k, alg, variant).unwrap();
            for m in &models {
                let got = convolve_parallel(m.as_ref(), &img, &k, alg, variant, Layout::PerPlane)
                    .unwrap();
                assert_eq!(got, want, "{} {alg:?} {variant:?}", m.name());
            }
        }
    }
}

#[test]
fn thread_count_never_changes_pixels() {
    let img = synth_image(3, 41, 29, Pattern::Checker, 2);
    let k = k5();
    let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
    for threads in [1usize, 2, 3, 7, 16, 64] {
        let m = OpenMpModel::new(threads);
        let got =
            convolve_parallel(&m, &img, &k, Algorithm::TwoPass, Variant::Simd, Layout::PerPlane)
                .unwrap();
        assert_eq!(got, want, "{threads} threads");
    }
}

#[test]
fn gprm_cutoff_never_changes_pixels() {
    let img = synth_image(3, 41, 29, Pattern::Noise, 3);
    let k = k5();
    let want = convolve_image(img.clone(), &k, Algorithm::SinglePassNoCopy, Variant::Simd).unwrap();
    for cutoff in [1usize, 2, 41, 100, 480] {
        let m = GprmModel::new(4, cutoff);
        let got = convolve_parallel(
            &m,
            &img,
            &k,
            Algorithm::SinglePassNoCopy,
            Variant::Simd,
            Layout::PerPlane,
        )
        .unwrap();
        assert_eq!(got, want, "cutoff {cutoff}");
    }
}

#[test]
fn opencl_local_size_never_changes_pixels() {
    let img = synth_image(3, 41, 29, Pattern::Disc, 4);
    let k = k5();
    let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Scalar).unwrap();
    for local in [1usize, 2, 16, 41, 64] {
        let m = OpenClModel::new(3, local);
        let got =
            convolve_parallel(&m, &img, &k, Algorithm::TwoPass, Variant::Scalar, Layout::PerPlane)
                .unwrap();
        assert_eq!(got, want, "local_size {local}");
    }
}

#[test]
fn agglomerated_layout_consistent_across_models() {
    // all three models agree with each other bit-for-bit under 3RxC
    let img = synth_image(3, 40, 32, Pattern::Noise, 5);
    let k = k5();
    let m1 = OpenMpModel::new(4);
    let want =
        convolve_parallel(&m1, &img, &k, Algorithm::TwoPass, Variant::Simd, Layout::Agglomerated)
            .unwrap();
    let m2 = OpenClModel::new(2, 8);
    let m3 = GprmModel::new(3, 10);
    for m in [&m2 as &dyn ExecutionModel, &m3] {
        let got =
            convolve_parallel(m, &img, &k, Algorithm::TwoPass, Variant::Simd, Layout::Agglomerated)
                .unwrap();
        assert_eq!(got, want, "{}", m.name());
    }
}

#[test]
fn tiny_images_survive_every_model() {
    // 6x6: interior is 2x2; 5x5: interior is 1x1; 4x4: no interior at all
    let k = k5();
    for size in [4usize, 5, 6] {
        let img = synth_image(3, size, size, Pattern::Noise, 6);
        let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        for m in [
            Box::new(OpenMpModel::new(8)) as Box<dyn ExecutionModel>,
            Box::new(OpenClModel::new(4, 3)),
            Box::new(GprmModel::new(4, 100)),
        ] {
            let got = convolve_parallel(m.as_ref(), &img, &k, Algorithm::TwoPass, Variant::Simd, Layout::PerPlane)
                .unwrap();
            assert_eq!(got, want, "{} at {size}", m.name());
        }
        if size == 4 {
            // no interior: output must equal input
            assert_eq!(want, img);
        }
    }
}

#[test]
fn single_plane_and_many_planes() {
    let k = k5();
    for planes in [1usize, 2, 5] {
        let img = synth_image(planes, 24, 24, Pattern::Noise, 7);
        let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        let m = GprmModel::new(3, 9);
        let got = convolve_parallel(&m, &img, &k, Algorithm::TwoPass, Variant::Simd, Layout::PerPlane)
            .unwrap();
        assert_eq!(got, want, "{planes} planes");
        // agglomerated works for any plane count too
        let agg = convolve_parallel(&m, &img, &k, Algorithm::TwoPass, Variant::Simd, Layout::Agglomerated)
            .unwrap();
        assert_eq!(agg.planes, planes);
    }
}

#[test]
fn repeated_convolution_converges_to_flat() {
    // Gaussian blur applied repeatedly flattens the interior (heat
    // diffusion) — a cross-model behavioural sanity, not just equality
    let k = k5();
    let mut img = synth_image(1, 32, 32, Pattern::Checker, 8);
    let m = OpenMpModel::new(4);
    let initial_var = variance(&img.data);
    for _ in 0..30 {
        img = convolve_parallel(&m, &img, &k, Algorithm::TwoPass, Variant::Simd, Layout::PerPlane)
            .unwrap();
    }
    // interior variance collapses
    let mut inner = vec![];
    for i in 8..24 {
        for j in 8..24 {
            inner.push(img.get(0, i, j));
        }
    }
    assert!(variance(&inner) < initial_var * 0.05);
}

fn variance(xs: &[f32]) -> f32 {
    let m = xs.iter().sum::<f32>() / xs.len() as f32;
    xs.iter().map(|x| (x - m).powi(2)).sum::<f32>() / xs.len() as f32
}
