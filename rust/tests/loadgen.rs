//! Load-harness suite (tier-1, wired into scripts/verify.sh): the
//! scale-factor traffic generator driving the real coordinator end to
//! end, in both driver models —
//!
//! * accounting: every issued request resolves to exactly one of
//!   served / shed / expired, refusals are structured (`failed == 0`),
//!   and the coordinator's own counters agree with the driver's tally,
//! * SLO quoting: p50 ≤ p95 ≤ p99, finite, inside `[min, max]`,
//! * batching: a Zipf-skewed hot shape must actually coalesce
//!   (batch sizes > 1) — the mix exists to exercise plan-keyed
//!   batching, not defeat it,
//! * determinism: the same seed reproduces the schedule bitwise; the
//!   result carries the plan digest as the regression handle.
//!
//! Image sizes are kept small so the suite stays fast at
//! `PHI_THREADS=1` — correctness here is about accounting, not
//! throughput (the `loadgen` bench quotes the real curves).

use phi_conv::config::RunConfig;
use phi_conv::loadgen::{run_mode, run_scales, MixConfig, Mode, RequestPlan};
use phi_conv::models::test_threads;

/// Small, fast mix: generous deadlines and ample queue capacity so a
/// healthy run serves everything — shed/expired legs live in the
/// queue_stress suite where overload is constructed deliberately.
fn fast_mix() -> MixConfig {
    MixConfig {
        min_size: 24,
        max_size: 48,
        widths: vec![3, 5],
        // default tail widths reach 25, which doesn't fit min_size 24
        tail_widths: vec![11, 17],
        deadline_ms: 60_000,
        requests_per_scale: 24,
        rate_per_s: 2000.0,
        ..MixConfig::default()
    }
}

fn cfg() -> RunConfig {
    RunConfig {
        threads: test_threads(2),
        queue_capacity: 512,
        batch_max: 4,
        ..RunConfig::default()
    }
}

#[test]
fn every_issued_request_is_accounted_for_in_both_modes() {
    let mix = fast_mix();
    let results = run_scales(&cfg(), &mix, &[1, 2], &[Mode::Open, Mode::Closed], 2, None).unwrap();
    assert_eq!(results.len(), 4, "two scales x two modes");
    for r in &results {
        let plan = RequestPlan::generate(&mix, r.scale).unwrap();
        assert_eq!(r.issued, plan.issued());
        assert_eq!(
            r.resolved() as usize,
            r.issued,
            "scale {} {}: served+shed+expired+failed must equal issued",
            r.scale,
            r.mode.label()
        );
        assert_eq!(r.failed, 0, "refusals must be structured");
        // generous deadlines + capacity far beyond the plan: a healthy
        // run serves everything, so the identity is exact
        assert_eq!((r.shed, r.expired), (0, 0), "scale {} {}", r.scale, r.mode.label());
        assert_eq!(r.served as usize, r.issued);
        // the coordinator's own counters must agree with the tally
        assert_eq!(r.stats.served, r.served);
        assert_eq!(r.stats.errors, 0);
        assert_eq!(r.hist.count(), r.served);
        assert_eq!(r.latency.len() as u64, r.served);
        // graph requests route through the DAG path...
        assert_eq!(r.stats.graphs_served as usize, plan.graph_count());
        // ...and everything else resolves a tuning decision: with no
        // cost model installed they all land on `default`
        assert_eq!(
            (r.stats.plans_predicted + r.stats.plans_swept + r.stats.plans_default) as usize,
            plan.issued() - plan.graph_count(),
            "decision counters must cover every non-graph request"
        );
        assert_eq!(r.stats.plans_predicted, 0, "untuned run cannot predict");
    }
}

#[test]
fn quoted_percentiles_are_finite_ordered_and_in_range() {
    let mix = fast_mix();
    let r = {
        let plan = RequestPlan::generate(&mix, 2).unwrap();
        run_mode(&cfg(), &plan, Mode::Open, 2, None).unwrap()
    };
    assert!(r.served > 0);
    let p50 = r.hist.percentile(50.0).expect("non-empty run has a p50");
    let p95 = r.hist.percentile(95.0).unwrap();
    let p99 = r.hist.percentile(99.0).unwrap();
    assert!(p50.is_finite() && p95.is_finite() && p99.is_finite());
    assert!(p50 <= p95 && p95 <= p99, "p50={p50} p95={p95} p99={p99}");
    assert!(r.hist.min().unwrap() <= p50 && p99 <= r.hist.max().unwrap());
    // the exact SampleSet agrees on ordering (it is the same data)
    let e50 = r.latency.percentile_checked(50.0).unwrap();
    let e99 = r.latency.percentile_checked(99.0).unwrap();
    assert!(e50 <= e99);
    assert!(r.wall_ms > 0.0);
    assert!(r.throughput_rps() > 0.0);
}

#[test]
fn hot_shape_skew_coalesces_into_batches() {
    // sharp skew, one kernel width, no graphs, no tail draws or class
    // pins: ~89% of requests share one PlanKey. Open loop at a rate far
    // beyond one executor's service rate piles them up in the queue, so
    // the executor must coalesce same-key neighbours when it comes free.
    let mix = MixConfig {
        shape_count: 2,
        zipf_s: 3.0,
        min_size: 48,
        max_size: 64,
        widths: vec![5],
        graph_fraction: 0.0,
        tail_fraction: 0.0,
        direct2d_fraction: 0.0,
        deadline_ms: 0,
        requests_per_scale: 128,
        rate_per_s: 1e6,
        ..MixConfig::default()
    };
    let plan = RequestPlan::generate(&mix, 1).unwrap();
    let counts = plan.shape_counts();
    assert!(
        counts[0] > plan.issued() / 2,
        "zipf_s=3 over 2 shapes must make shape 0 hot, got {counts:?}"
    );
    let cfg = RunConfig { batch_max: 8, ..cfg() };
    let r = run_mode(&cfg, &plan, Mode::Open, 1, None).unwrap();
    assert_eq!(r.resolved() as usize, r.issued);
    assert_eq!(r.failed, 0);
    assert!(!r.stats.batch_sizes.is_empty());
    assert!(
        r.stats.batch_sizes.max() >= 2.0,
        "hot-shape flood into one executor must coalesce, max batch {}",
        r.stats.batch_sizes.max()
    );
}

#[test]
fn same_seed_reproduces_the_schedule_bitwise() {
    let mix = fast_mix();
    let a = RequestPlan::generate(&mix, 3).unwrap();
    let b = RequestPlan::generate(&mix, 3).unwrap();
    assert_eq!(a, b, "same (seed, scale) must yield an identical schedule");
    assert_eq!(a.digest(), b.digest());

    let other = MixConfig { seed: mix.seed + 1, ..mix.clone() };
    let c = RequestPlan::generate(&other, 3).unwrap();
    assert_ne!(a.digest(), c.digest(), "a different seed must change the schedule");

    // the digest rides into the result — two runs of the same plan
    // report the same regression handle even though latencies differ
    let r1 = run_mode(&cfg(), &a, Mode::Closed, 1, None).unwrap();
    let r2 = run_mode(&cfg(), &b, Mode::Closed, 1, None).unwrap();
    assert_eq!(r1.plan_digest, a.digest());
    assert_eq!(r1.plan_digest, r2.plan_digest);
    assert_eq!(r1.issued, r2.issued);
    assert_eq!(r1.served, r2.served);
}
