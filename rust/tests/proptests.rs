//! Property-based tests over randomised cases.
//!
//! The offline build has no `proptest` crate, so these use the in-tree
//! PRNG (`util::prng`) to generate many random cases per property with a
//! fixed seed — deterministic, shrink-free property testing. Each
//! property states its invariant in the test name; failures print the
//! offending case's parameters.

use std::sync::Mutex;

use phi_conv::conv::{convolve_image, Algorithm, Variant};
use phi_conv::image::{gaussian_kernel, synth_image, Pattern, PlanarImage};
use phi_conv::models::{
    convolve_parallel, static_chunk, ExecutionModel, GprmModel, Layout, OpenClModel, OpenMpModel,
};
use phi_conv::phisim::{simulate, Calibration, PhiMachine, SimRun, SimWorkload};
use phi_conv::plan::{ConvPlan, FilterGraph, KernelSpec, ScratchArena};
use phi_conv::util::json::Json;
use phi_conv::util::prng::Prng;

const CASES: usize = 40;

// ---------------------------------------------------------------------------
// models: partition invariants
// ---------------------------------------------------------------------------

/// Every execution model's dispatch covers [0, n) exactly once — no gaps,
/// no overlaps — for arbitrary n, worker counts and granularity knobs.
#[test]
fn prop_every_model_covers_rows_exactly_once() {
    let mut rng = Prng::new(0xC0FFEE);
    for case in 0..CASES {
        let n = rng.range(0, 300);
        let threads = rng.range(1, 9);
        let model: Box<dyn ExecutionModel> = match case % 3 {
            0 => Box::new(OpenMpModel::new(threads)),
            1 => Box::new(OpenClModel::new(threads, rng.range(1, 40))),
            _ => Box::new(GprmModel::new(threads, rng.range(1, 300))),
        };
        let hits = Mutex::new(vec![0u32; n]);
        model.dispatch(n, &|a, b| {
            assert!(a <= b && b <= n, "range ({a},{b}) out of [0,{n})");
            let mut h = hits.lock().unwrap();
            for i in a..b {
                h[i] += 1;
            }
        });
        let h = hits.lock().unwrap();
        assert!(
            h.iter().all(|&c| c == 1),
            "case {case}: {} n={n} threads={threads}: cover counts {:?}",
            model.name(),
            h.iter().enumerate().filter(|(_, &c)| c != 1).take(5).collect::<Vec<_>>()
        );
    }
}

/// static_chunk is a partition for arbitrary (n, parts).
#[test]
fn prop_static_chunk_partition() {
    let mut rng = Prng::new(7);
    for _ in 0..200 {
        let n = rng.range(0, 1000);
        let parts = rng.range(1, 513);
        let mut prev_end = 0;
        for t in 0..parts {
            let (a, b) = static_chunk(n, parts, t);
            assert_eq!(a, prev_end, "chunks must be contiguous");
            assert!(b >= a);
            prev_end = b;
        }
        assert_eq!(prev_end, n);
    }
}

// ---------------------------------------------------------------------------
// parallel == sequential, randomised
// ---------------------------------------------------------------------------

/// Any model / any knobs / any shape: parallel pixels == sequential
/// pixels (PerPlane layout).
#[test]
fn prop_parallel_equals_sequential() {
    let mut rng = Prng::new(0xBEEF);
    let k = gaussian_kernel(5, 1.0);
    for case in 0..20 {
        let rows = rng.range(6, 70);
        let cols = rng.range(6, 70);
        let planes = rng.range(1, 4);
        let img = synth_image(planes, rows, cols, Pattern::Noise, case as u64);
        let threads = rng.range(1, 7);
        let model: Box<dyn ExecutionModel> = match case % 3 {
            0 => Box::new(OpenMpModel::new(threads)),
            1 => Box::new(OpenClModel::new(threads, rng.range(1, 20))),
            _ => Box::new(GprmModel::new(threads, rng.range(1, 200))),
        };
        let alg = *rng.pick(&[
            Algorithm::TwoPass,
            Algorithm::SinglePassCopyBack,
            Algorithm::SinglePassNoCopy,
        ]);
        let variant = *rng.pick(&[Variant::Scalar, Variant::Simd]);
        let want = convolve_image(img.clone(), &k, alg, variant).unwrap();
        let got = convolve_parallel(model.as_ref(), &img, &k, alg, variant, Layout::PerPlane)
            .unwrap();
        assert_eq!(
            got,
            want,
            "case {case}: {} {rows}x{cols}x{planes} {alg:?} {variant:?}",
            model.name()
        );
    }
}

// ---------------------------------------------------------------------------
// layout transforms
// ---------------------------------------------------------------------------

/// agglomerate ∘ deagglomerate == identity for arbitrary shapes.
#[test]
fn prop_agglomeration_roundtrip() {
    let mut rng = Prng::new(0xA66);
    for case in 0..CASES {
        let planes = rng.range(1, 6);
        let rows = rng.range(1, 40);
        let cols = rng.range(1, 40);
        let img = synth_image(planes, rows, cols, Pattern::Noise, case as u64);
        let wide = img.agglomerate();
        assert_eq!(wide.len(), planes * rows * cols);
        let back = PlanarImage::from_agglomerated(planes, rows, cols, &wide).unwrap();
        assert_eq!(back, img, "case {case}: {planes}x{rows}x{cols}");
    }
}

// ---------------------------------------------------------------------------
// simulator invariants
// ---------------------------------------------------------------------------

/// Busy time never increases with more threads (the overhead term may,
/// but raw compute+memory cannot).
#[test]
fn prop_sim_busy_monotone_in_threads() {
    let mut rng = Prng::new(0x51);
    let m = PhiMachine::default();
    let cal = Calibration::default();
    for _ in 0..CASES {
        let size = *rng.pick(&[1152usize, 2592, 5832, 8748]);
        let alg = *rng.pick(&[Algorithm::TwoPass, Algorithm::SinglePassNoCopy]);
        let variant = *rng.pick(&[Variant::Scalar, Variant::Simd]);
        let w = SimWorkload::paper(size, alg, variant);
        let mut prev = f64::INFINITY;
        for threads in [1usize, 10, 50, 100, 200, 240] {
            let e = simulate(&m, &cal, &w, &SimRun::openmp(threads));
            assert!(
                e.busy_ms <= prev + 1e-9,
                "busy went up at {threads} threads ({size}, {alg:?}, {variant:?})"
            );
            prev = e.busy_ms;
        }
    }
}

/// GPRM overhead is linear in the cutoff and amortised 3× by
/// agglomeration, for any workload.
#[test]
fn prop_sim_gprm_overhead_structure() {
    let mut rng = Prng::new(0x52);
    let m = PhiMachine::default();
    let cal = Calibration::default();
    for _ in 0..CASES {
        let size = *rng.pick(&[1152usize, 3888, 8748]);
        let w = SimWorkload::paper(size, Algorithm::TwoPass, Variant::Simd);
        let c1 = rng.range(10, 200);
        let c2 = c1 * 2;
        let o1 = simulate(&m, &cal, &w, &SimRun::gprm(c1, Layout::PerPlane)).overhead_ms;
        let o2 = simulate(&m, &cal, &w, &SimRun::gprm(c2, Layout::PerPlane)).overhead_ms;
        // linear with positive intercept: o2 < 2*o1, o2 > o1
        assert!(o2 > o1 && o2 < 2.0 * o1 + 1e-9, "cutoff {c1}->{c2}: {o1} -> {o2}");
        let rxc = simulate(&m, &cal, &w, &SimRun::gprm(c1, Layout::PerPlane)).overhead_ms;
        let agg = simulate(&m, &cal, &w, &SimRun::gprm(c1, Layout::Agglomerated)).overhead_ms;
        assert!((rxc / agg - 3.0).abs() < 1e-9, "agglomeration must cut overhead 3x");
    }
}

/// The GPRM-vs-OpenMP crossover exists and is monotone: once GPRM(3R×C)
/// wins at some size, it keeps winning at every larger size.
#[test]
fn prop_sim_agglomeration_crossover_monotone() {
    let m = PhiMachine::default();
    let cal = Calibration::default();
    let mut won = false;
    for size in [576usize, 1152, 1728, 2592, 3888, 5832, 8748, 12000, 16000] {
        let w = SimWorkload::paper(size, Algorithm::TwoPass, Variant::Simd);
        let omp = simulate(&m, &cal, &w, &SimRun::openmp(100)).total_ms();
        let gprm = simulate(&m, &cal, &w, &SimRun::gprm(100, Layout::Agglomerated)).total_ms();
        let wins = gprm < omp;
        assert!(!won || wins, "GPRM stopped winning at {size} after winning earlier");
        won = won || wins;
    }
    assert!(won, "GPRM+agglomeration must win somewhere (paper: at 8748)");
}

// ---------------------------------------------------------------------------
// util substrates
// ---------------------------------------------------------------------------

fn random_json(rng: &mut Prng, depth: usize) -> Json {
    if depth == 0 {
        return match rng.below(4) {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.range(0, 100000) as f64) / 8.0),
            _ => Json::Str(format!("s{}", rng.range(0, 999))),
        };
    }
    match rng.below(2) {
        0 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.below(5))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

/// JSON display ∘ parse == identity for random documents.
#[test]
fn prop_json_roundtrip() {
    let mut rng = Prng::new(0x77);
    for case in 0..100 {
        let v = random_json(&mut rng, 3);
        let text = v.to_string();
        let re = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(v, re, "case {case}: {text}");
    }
}

// ---------------------------------------------------------------------------
// cross-engine equivalence: every variant and every execution model must
// stay within 1e-4 of the naive reference on randomised images
// ---------------------------------------------------------------------------

/// Naive single-pass with copy-back: the paper's Opt-0, used as the
/// numeric reference for the whole ladder.
fn naive_reference(img: &PlanarImage, k: &[f32]) -> PlanarImage {
    convolve_image(img.clone(), k, Algorithm::SinglePassCopyBack, Variant::Naive).unwrap()
}

/// Every sequential conv variant agrees with the naive reference: the
/// single-pass rungs pixel-for-pixel (identical border handling), the
/// two-pass rungs on the deep interior (border treatment differs by
/// construction).
#[test]
fn prop_every_conv_variant_matches_naive_reference() {
    let mut rng = Prng::new(0xD1CE);
    let k = gaussian_kernel(5, 1.0);
    for case in 0..CASES {
        let rows = rng.range(10, 60);
        let cols = rng.range(10, 60);
        let planes = rng.range(1, 4);
        let img = synth_image(planes, rows, cols, Pattern::Noise, 1000 + case as u64);
        let want = naive_reference(&img, &k);
        for (alg, variant) in [
            (Algorithm::SinglePassCopyBack, Variant::Scalar),
            (Algorithm::SinglePassCopyBack, Variant::Simd),
            (Algorithm::SinglePassNoCopy, Variant::Scalar),
            (Algorithm::SinglePassNoCopy, Variant::Simd),
        ] {
            let out = convolve_image(img.clone(), &k, alg, variant).unwrap();
            let d = out.max_abs_diff(&want);
            assert!(d < 1e-4, "case {case}: {alg:?} {variant:?} vs naive: {d}");
        }
        for variant in [Variant::Scalar, Variant::Simd] {
            let out = convolve_image(img.clone(), &k, Algorithm::TwoPass, variant).unwrap();
            let d = out.max_abs_diff_deep(&want, 2);
            assert!(d < 1e-4, "case {case}: two-pass {variant:?} vs naive (deep): {d}");
        }
    }
}

/// Every execution model × both layouts (GPRM agglomeration on and off,
/// and the same axis for OpenMP/OpenCL) stays within 1e-4 of the naive
/// reference on the deep interior — randomised shapes, thread counts and
/// granularity knobs.
#[test]
fn prop_every_execution_model_matches_naive_reference() {
    let mut rng = Prng::new(0xE0E0);
    let k = gaussian_kernel(5, 1.0);
    for case in 0..12 {
        let rows = rng.range(12, 50);
        let cols = rng.range(12, 50);
        let img = synth_image(3, rows, cols, Pattern::Noise, 2000 + case as u64);
        let want = naive_reference(&img, &k);
        let threads = rng.range(1, 6);
        let models: Vec<Box<dyn ExecutionModel>> = vec![
            Box::new(OpenMpModel::new(threads)),
            Box::new(OpenClModel::new(threads, rng.range(1, 16))),
            Box::new(GprmModel::new(threads, rng.range(1, 120))),
        ];
        let variant = *rng.pick(&[Variant::Scalar, Variant::Simd]);
        for m in &models {
            for layout in [Layout::PerPlane, Layout::Agglomerated] {
                for alg in [Algorithm::SinglePassNoCopy, Algorithm::TwoPass] {
                    let out =
                        convolve_parallel(m.as_ref(), &img, &k, alg, variant, layout).unwrap();
                    // deep interior: clear of borders and, for 3R×C, of
                    // the plane seams (both are within 2·halo = 4 px)
                    let d = out.max_abs_diff_deep(&want, 2);
                    assert!(
                        d < 1e-4,
                        "case {case}: {} {alg:?} {variant:?} {layout:?} vs naive: {d}",
                        m.name()
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// plan layer: cross-width equivalence + scratch-arena discipline
// ---------------------------------------------------------------------------

/// Generic-width engines at widths 3/7/9 agree with the naive generic
/// reference within 1e-4 — single-pass rungs pixel-for-pixel, two-pass
/// on the deep interior — across random shapes, both sequentially and
/// under every execution model.
#[test]
fn prop_generic_widths_match_naive_reference() {
    let mut rng = Prng::new(0x71D5);
    for width in [3usize, 7, 9] {
        let k = gaussian_kernel(width, 0.5 + width as f64 / 4.0);
        let h = width / 2;
        for case in 0..8 {
            let rows = rng.range(4 * width, 4 * width + 30);
            let cols = rng.range(4 * width, 4 * width + 30);
            let planes = rng.range(1, 4);
            let img = synth_image(planes, rows, cols, Pattern::Noise, 3000 + case as u64);
            let want =
                convolve_image(img.clone(), &k, Algorithm::SinglePassCopyBack, Variant::Naive)
                    .unwrap();
            let mut arena = ScratchArena::new();
            for variant in [Variant::Scalar, Variant::Simd] {
                for alg in [Algorithm::SinglePassCopyBack, Algorithm::SinglePassNoCopy] {
                    let plan = ConvPlan::builder()
                        .algorithm(alg)
                        .variant(variant)
                        .kernel_taps(k.clone())
                        .shape(planes, rows, cols)
                        .build()
                        .unwrap();
                    assert!(!plan.is_fast_path(), "width {width} must take the generic path");
                    let out = plan.execute(&img, &mut arena).unwrap();
                    let d = out.max_abs_diff(&want);
                    assert!(d < 1e-4, "w{width} case {case}: {alg:?} {variant:?}: {d}");
                }
                let plan = ConvPlan::builder()
                    .algorithm(Algorithm::TwoPass)
                    .variant(variant)
                    .kernel_taps(k.clone())
                    .shape(planes, rows, cols)
                    .build()
                    .unwrap();
                let out = plan.execute(&img, &mut arena).unwrap();
                let d = out.max_abs_diff_deep(&want, h);
                assert!(d < 1e-4, "w{width} case {case}: two-pass {variant:?} deep: {d}");
                // parallel execution agrees bit-for-bit with sequential
                let model = OpenMpModel::new(rng.range(1, 6));
                let par = plan.execute_on(&model, &img, &mut arena).unwrap();
                assert_eq!(par, out, "w{width} case {case}: parallel != sequential");
            }
        }
    }
}

/// The width-5 unrolled fast path and the forced-generic path compute
/// the same pixels within 1e-4 for every algorithm × variant.
#[test]
fn prop_width5_fast_path_matches_generic_path() {
    let mut rng = Prng::new(0xFA57);
    for case in 0..10 {
        let rows = rng.range(12, 50);
        let cols = rng.range(12, 50);
        let planes = rng.range(1, 4);
        let img = synth_image(planes, rows, cols, Pattern::Noise, 4000 + case as u64);
        let mut arena = ScratchArena::new();
        for variant in [Variant::Scalar, Variant::Simd] {
            for alg in
                [Algorithm::TwoPass, Algorithm::SinglePassCopyBack, Algorithm::SinglePassNoCopy]
            {
                let build = |generic: bool| {
                    ConvPlan::builder()
                        .algorithm(alg)
                        .variant(variant)
                        .kernel(KernelSpec::new(5, 1.0))
                        .shape(planes, rows, cols)
                        .force_generic(generic)
                        .build()
                        .unwrap()
                };
                let fast = build(false);
                let generic = build(true);
                assert!(fast.is_fast_path() && !generic.is_fast_path());
                let a = fast.execute(&img, &mut arena).unwrap();
                let b = generic.execute(&img, &mut arena).unwrap();
                let d = a.max_abs_diff(&b);
                assert!(d < 1e-4, "case {case}: {alg:?} {variant:?} fast vs generic: {d}");
            }
        }
    }
}

/// Arena discipline: repeated `execute`/`execute_on`/`execute_batch`
/// calls never allocate scratch after warm-up, across every algorithm
/// and layout at a fixed shape.
#[test]
fn prop_scratch_arena_never_grows_after_warmup() {
    let img = synth_image(3, 40, 36, Pattern::Noise, 77);
    let model = OpenMpModel::new(3);
    let mut arena = ScratchArena::new();
    let mut plans = Vec::new();
    for layout in [Layout::PerPlane, Layout::Agglomerated] {
        for alg in [Algorithm::TwoPass, Algorithm::SinglePassCopyBack, Algorithm::SinglePassNoCopy]
        {
            plans.push(
                ConvPlan::builder()
                    .algorithm(alg)
                    .layout(layout)
                    .shape(3, 40, 36)
                    .build()
                    .unwrap(),
            );
        }
    }
    // warm-up: one sequential + one parallel pass over every plan
    for plan in &plans {
        plan.execute(&img, &mut arena).unwrap();
        plan.execute_on(&model, &img, &mut arena).unwrap();
    }
    let warm = arena.allocations();
    // both layouts share one buffer size here (planes*rows*cols), so the
    // whole mix needs exactly two scratch planes
    assert_eq!(warm, 2, "expected 2 scratch planes, got {warm}");
    let batch: Vec<PlanarImage> = vec![img.clone(), img.clone()];
    for _ in 0..5 {
        for plan in &plans {
            plan.execute(&img, &mut arena).unwrap();
            plan.execute_on(&model, &img, &mut arena).unwrap();
            plan.execute_batch(Some(&model), &batch, &mut arena).unwrap();
        }
    }
    assert_eq!(arena.allocations(), warm, "steady state allocated scratch");
}

// ---------------------------------------------------------------------------
// graph layer: builder rejections + streamed chains vs a staged reference
// ---------------------------------------------------------------------------

/// The GraphBuilder rejects malformed graphs for arbitrary shapes:
/// empty graphs, even-width stages, shape-mismatched edges, self-reads
/// and two-stage cycles all fail `build()` with a structured error.
#[test]
fn prop_graph_builder_rejects_malformed_graphs() {
    let mut rng = Prng::new(0x6AF);
    for case in 0..CASES {
        let rows = rng.range(8, 40);
        let cols = rng.range(8, 40);
        assert!(
            FilterGraph::builder().shape(1, rows, cols).build().is_err(),
            "case {case}: empty graph must be rejected"
        );
        let even = FilterGraph::builder()
            .shape(1, rows, cols)
            .stage("a", KernelSpec::new(2 * rng.range(1, 5), 1.0))
            .build();
        assert!(even.is_err(), "case {case}: even width must be rejected");
        let mismatch = FilterGraph::builder()
            .shape(1, rows, cols)
            .stage("a", KernelSpec::new(3, 1.0))
            .expect_shape(1, rows + rng.range(1, 9), cols)
            .build();
        assert!(mismatch.is_err(), "case {case}: edge shape mismatch must be rejected");
        let self_read = FilterGraph::builder()
            .shape(1, rows, cols)
            .stage("a", KernelSpec::new(3, 1.0))
            .after("a")
            .build();
        assert!(self_read.is_err(), "case {case}: self-read must be rejected");
        let cycle = FilterGraph::builder()
            .shape(1, rows, cols)
            .stage("a", KernelSpec::new(3, 1.0))
            .after("b")
            .stage("b", KernelSpec::new(3, 1.0))
            .build();
        assert!(cycle.is_err(), "case {case}: 2-cycle must be rejected");
    }
}

/// Rewiring any stage of a random linear chain to read a later stage
/// closes a cycle (stages have one input each), which `build()` must
/// reject via Kahn leftovers.
#[test]
fn prop_graph_builder_rejects_random_back_edges() {
    let mut rng = Prng::new(0xC1C1E);
    for case in 0..CASES {
        let n = rng.range(2, 7);
        let i = rng.range(0, n - 1);
        let j = rng.range(i + 1, n);
        let mut b = FilterGraph::builder().shape(1, 30, 30);
        for s in 0..n {
            b = b.stage(&format!("s{s}"), KernelSpec::new(3, 1.0));
            if s == i {
                // forward reference: s_i reads s_j (j > i), while
                // s_{i+1}..s_j still chain back to s_i — a cycle
                b = b.after(&format!("s{j}"));
            }
        }
        let e = b.build();
        assert!(e.is_err(), "case {case}: back edge s{i} -> s{j} of {n} must cycle");
    }
}

/// Plain-loop two-pass for one stage, the semantics every engine in the
/// repo implements: horizontal then vertical over the deep interior,
/// everything else passing through from the *source* plane, and a
/// kernel that doesn't fit acting as the identity.
fn stage_twopass_reference(src: &[f32], rows: usize, cols: usize, taps: &[f32]) -> Vec<f32> {
    let h = taps.len() / 2;
    if 2 * h >= rows || 2 * h >= cols {
        return src.to_vec();
    }
    let mut b = src.to_vec();
    for i in h..rows - h {
        for j in h..cols - h {
            let mut s = 0.0f32;
            for (v, &kv) in taps.iter().enumerate() {
                s += src[i * cols + j - h + v] * kv;
            }
            b[i * cols + j] = s;
        }
    }
    let mut out = src.to_vec();
    for i in h..rows - h {
        for j in h..cols - h {
            let mut s = 0.0f32;
            for (u, &ku) in taps.iter().enumerate() {
                s += b[(i + u - h) * cols + j] * ku;
            }
            out[i * cols + j] = s;
        }
    }
    out
}

/// Random taps for a chain stage, normalised to Σ|t| = 1 so chained
/// stages stay well-conditioned and 1e-6 remains a meaningful bound.
fn random_taps(rng: &mut Prng, width: usize) -> Vec<f32> {
    let mut t: Vec<f32> =
        (0..width).map(|_| rng.range(0, 2001) as f32 / 1000.0 - 1.0).collect();
    t[width / 2] += 1.5;
    let norm: f32 = t.iter().map(|v| v.abs()).sum();
    for v in &mut t {
        *v /= norm;
    }
    t
}

/// Random linear odd-width chains: the streamed FilterGraph agrees with
/// the plain-loop staged reference within 1e-6 on arbitrary shapes,
/// stage counts, widths and taps — and banded execution agrees with
/// sequential bitwise.
#[test]
fn prop_random_chains_match_staged_reference() {
    let mut rng = Prng::new(0x6409);
    for case in 0..20 {
        let rows = rng.range(14, 48);
        let cols = rng.range(14, 48);
        let planes = rng.range(1, 3);
        let n = rng.range(2, 5);
        let img = synth_image(planes, rows, cols, Pattern::Noise, 5000 + case as u64);
        let mut b = FilterGraph::builder().shape(planes, rows, cols);
        let mut stages: Vec<Vec<f32>> = Vec::new();
        for s in 0..n {
            let taps = random_taps(&mut rng, 2 * rng.range(1, 5) + 1);
            b = b.stage_taps(&format!("s{s}"), taps.clone());
            stages.push(taps);
        }
        let g = b.build().unwrap();
        let mut want = img.clone();
        for taps in &stages {
            let mut out = Vec::with_capacity(want.data.len());
            for p in 0..planes {
                out.extend(stage_twopass_reference(want.plane(p), rows, cols, taps));
            }
            want = PlanarImage::from_vec(planes, rows, cols, out).unwrap();
        }
        let mut arena = ScratchArena::new();
        let seq = g.execute_single(None, &img, &mut arena).unwrap();
        let d = seq.max_abs_diff(&want);
        assert!(d <= 1e-6, "case {case}: {n}-stage {rows}x{cols} chain vs reference: {d}");
        let model = OpenMpModel::new(rng.range(1, 6));
        let par = g.execute_single(Some(&model), &img, &mut arena).unwrap();
        assert_eq!(par.data, seq.data, "case {case}: banded != sequential");
    }
}

// ---------------------------------------------------------------------------
// loadgen: the traffic-mix generator is a valid probability model
// ---------------------------------------------------------------------------

/// Random mix knobs: every generated schedule stays inside the model it
/// claims to draw from — shapes within bounds, widths odd and from the
/// mix's set (main or tail), class pins only on single-stage requests,
/// graph chains that pass GraphBuilder validation *and* build into
/// executable plans, nondecreasing arrivals, Zipf weights forming a
/// distribution, and a hot-shape empirical frequency that tracks the
/// nominal weight.
#[test]
fn prop_loadgen_mix_is_a_valid_probability_model() {
    use phi_conv::coordinator::GraphSpec;
    use phi_conv::loadgen::{MixConfig, RequestPlan};
    use phi_conv::plan::KernelClass;

    let mut rng = Prng::new(0x10AD);
    for case in 0..25 {
        let min_size = rng.range(24, 48);
        // tail widths must stay odd and below the smallest shape edge
        let tail_widths: Vec<usize> =
            [11usize, 17, 25].iter().copied().filter(|&w| w < min_size).collect();
        let mix = MixConfig {
            seed: rng.below(1 << 31) as u64,
            shape_count: rng.range(2, 6),
            min_size,
            max_size: min_size + rng.range(16, 64),
            zipf_s: rng.range(5, 25) as f64 / 10.0,
            graph_fraction: rng.range(0, 4) as f64 / 10.0,
            tail_widths,
            tail_fraction: rng.range(0, 3) as f64 / 10.0,
            direct2d_fraction: rng.range(0, 4) as f64 / 10.0,
            requests_per_scale: 64,
            ..MixConfig::default()
        };
        let scale = rng.range(2, 5);
        let plan = RequestPlan::generate(&mix, scale)
            .unwrap_or_else(|e| panic!("case {case}: valid knobs must generate: {e:#}"));
        assert_eq!(plan.issued(), 64 * scale, "case {case}");
        assert_eq!(plan.shapes.len(), mix.shape_count, "case {case}");
        for s in &plan.shapes {
            assert_eq!(s.planes, mix.planes, "case {case}");
            assert!(
                (mix.min_size..=mix.max_size).contains(&s.rows)
                    && (mix.min_size..=mix.max_size).contains(&s.cols),
                "case {case}: shape {} outside [{}, {}]",
                s.label(),
                mix.min_size,
                mix.max_size
            );
        }
        // weights form a non-increasing distribution; index 0 is hot
        assert_eq!(plan.weights.len(), mix.shape_count);
        assert!((plan.weights.iter().sum::<f64>() - 1.0).abs() < 1e-9, "case {case}");
        for pair in plan.weights.windows(2) {
            assert!(pair[0] >= pair[1] - 1e-15, "case {case}: weights must be non-increasing");
        }
        let mut prev_arrival = 0u64;
        for r in &plan.requests {
            assert!(r.shape < plan.shapes.len(), "case {case}: shape index in bounds");
            let w = r.kernel.width;
            assert!(
                w % 2 == 1 && (mix.widths.contains(&w) || mix.tail_widths.contains(&w)),
                "case {case}: width {w}"
            );
            match r.kernel_class {
                None => {}
                Some(KernelClass::Direct2d) => {
                    assert!(r.graph.is_none(), "case {case}: class pins never ride graph requests")
                }
                Some(c) => panic!("case {case}: the mix only pins Direct2d, got {c:?}"),
            }
            if let Some(stages) = &r.graph {
                assert!(
                    (2..=3).contains(&stages.len()),
                    "case {case}: graph chains are 2-3 stages, got {}",
                    stages.len()
                );
                for k in stages {
                    assert!(
                        k.width % 2 == 1 && mix.widths.contains(&k.width),
                        "case {case}: graph stage width {}",
                        k.width
                    );
                }
                // the chain must survive the real GraphBuilder, and
                // build into an executable graph at the target shape
                let spec = GraphSpec::chain(stages.clone());
                spec.validate()
                    .unwrap_or_else(|e| panic!("case {case}: chain must validate: {e:#}"));
                let shape = plan.shapes[r.shape];
                spec.build(shape.planes, shape.rows, shape.cols, Variant::Simd, Layout::PerPlane)
                    .unwrap_or_else(|e| panic!("case {case}: chain must build: {e:#}"));
            }
            assert!(r.arrival_us >= prev_arrival, "case {case}: arrivals nondecreasing");
            prev_arrival = r.arrival_us;
            assert_eq!(r.deadline_ms, mix.deadline_ms, "case {case}");
        }
        // the hot shape's empirical frequency tracks its Zipf weight
        // (n >= 128, so 0.15 is many binomial standard deviations)
        let hot = plan.shape_counts()[0] as f64 / plan.issued() as f64;
        assert!(
            (hot - plan.weights[0]).abs() < 0.15,
            "case {case}: hot-shape frequency {hot:.3} vs weight {:.3}",
            plan.weights[0]
        );
    }
}

/// Convolution energy property across random inputs: a normalised
/// Gaussian never increases the max-abs pixel value of the interior.
#[test]
fn prop_blur_never_amplifies() {
    let mut rng = Prng::new(0x88);
    let k = gaussian_kernel(5, 1.0);
    for case in 0..CASES {
        let img = synth_image(1, 24, 24, Pattern::Noise, case as u64 + rng.below(1000) as u64);
        let max_in = img.data.iter().fold(0f32, |m, v| m.max(v.abs()));
        let out = convolve_image(img, &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        let max_out = out.data.iter().fold(0f32, |m, v| m.max(v.abs()));
        assert!(max_out <= max_in + 1e-5, "case {case}: {max_in} -> {max_out}");
    }
}
