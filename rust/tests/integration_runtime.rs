//! Integration: the runtime layer's manifest contract (always on) and —
//! when built with `--features pjrt` and real artifacts — every AOT
//! artifact executing through PJRT and matching the native engines
//! (DESIGN.md §2: "native Rust conv engines are numerics-validated
//! against the Pallas/PJRT artifacts").

use std::path::PathBuf;

use phi_conv::runtime::Manifest;

/// The crate's canonical example manifest plus stub artifact files in a
/// unique temp dir (shared writer: `runtime::manifest::write_example_manifest`).
fn write_fixture(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("phi_conv_it_runtime_{}_{tag}", std::process::id()));
    phi_conv::runtime::manifest::write_example_manifest(&dir);
    dir
}

#[test]
fn manifest_round_trip_through_public_api() {
    let dir = write_fixture("roundtrip");
    let m = Manifest::load(&dir).unwrap();
    assert_eq!(m.kernel_width, 5);
    assert_eq!(m.artifacts.len(), 6);
    assert_eq!(m.full_sizes(), vec![288, 576]);
    let name = m.full_image_name("twopass", 3, 288);
    let e = m.get(&name).unwrap();
    assert_eq!(e.inputs[0].shape, vec![3, 288, 288]);
    assert!(m.path_of(e).exists());
    // the embedded kernel must match the Rust generator (the Python
    // cross-check contract, testable without PJRT)
    let k = phi_conv::image::gaussian_kernel(m.kernel_width, m.gaussian_sigma);
    for (rust, reference) in k.iter().zip(&m.kernel_values) {
        assert!((rust - reference).abs() < 1e-7, "{rust} vs {reference}");
    }
}

#[test]
fn manifest_missing_dir_is_a_helpful_error() {
    let e = Manifest::load("/nonexistent/phi-conv-artifacts").unwrap_err();
    assert!(e.to_string().contains("make artifacts"), "{e}");
}

// ---------------------------------------------------------------------------
// Default build: the PJRT bridge is feature-gated; the stub must refuse
// loudly and the coordinator-facing surface must stay compilable.
// ---------------------------------------------------------------------------

#[cfg(not(feature = "pjrt"))]
mod gated {
    use super::*;
    use phi_conv::runtime::{EnginePool, PjrtHandle};

    #[test]
    fn pjrt_disabled_in_default_build() {
        assert!(!phi_conv::runtime::pjrt_enabled());
    }

    #[test]
    fn engine_pool_reports_the_feature_gate() {
        // even with a perfectly valid manifest on disk
        let dir = write_fixture("gate_pool");
        let e = EnginePool::open(&dir).unwrap_err();
        assert!(e.to_string().contains("--features pjrt"), "{e}");
    }

    #[test]
    fn actor_spawn_reports_the_feature_gate() {
        let dir = write_fixture("gate_actor");
        let e = PjrtHandle::spawn(&dir).unwrap_err();
        assert!(e.to_string().contains("pjrt"), "{e}");
    }

    #[test]
    fn default_artifacts_dir_points_into_the_crate() {
        // NOTE: deliberately no std::env::set_var here — mutating the
        // environment races sibling tests' getenv calls (UB on glibc);
        // the $PHI_CONV_ARTIFACTS override branch is a one-line env
        // read. Reading the environment is safe.
        if std::env::var("PHI_CONV_ARTIFACTS").is_ok() {
            eprintln!("skipping: PHI_CONV_ARTIFACTS is set in this environment");
            return;
        }
        let dir = phi_conv::runtime::manifest::default_artifacts_dir();
        assert!(dir.ends_with("artifacts"), "{}", dir.display());
    }
}

// ---------------------------------------------------------------------------
// `--features pjrt` with real artifacts (`make artifacts`): the original
// cross-layer numerics contract.
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod with_pjrt {
    use phi_conv::conv::{convolve_image, Algorithm, Variant};
    use phi_conv::image::{gaussian_kernel, synth_image, Pattern, PlanarImage};
    use phi_conv::models::{convolve_parallel, Layout, OpenMpModel};
    use phi_conv::runtime::{manifest::default_artifacts_dir, EnginePool, PjrtHandle};

    fn pool() -> EnginePool {
        EnginePool::open(default_artifacts_dir()).expect("run `make artifacts` first")
    }

    fn max_diff(a: &[f32], b: &[f32]) -> f32 {
        assert_eq!(a.len(), b.len());
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn kernel_values_match_python_reference() {
        let m = pool();
        let k = gaussian_kernel(m.manifest().kernel_width, m.manifest().gaussian_sigma);
        for (rust, python) in k.iter().zip(&m.manifest().kernel_values) {
            assert!((rust - python).abs() < 1e-7, "{rust} vs {python}");
        }
    }

    #[test]
    fn all_ablation_artifacts_match_native() {
        // every lowering variant (naive / fused / whole / gridded) of both
        // algorithms produces the same pixels as the native engines
        let pool = pool();
        let k = pool.manifest().kernel_values.clone();
        let entries: Vec<_> = pool
            .manifest()
            .by_role("ablation")
            .iter()
            .map(|e| {
                (
                    e.name.clone(),
                    e.algorithm.clone(),
                    e.meta_usize("rows").unwrap(),
                    e.meta_usize("planes").unwrap(),
                )
            })
            .collect();
        assert!(!entries.is_empty());
        for (name, algorithm, rows, planes) in entries {
            let img = synth_image(planes, rows, rows, Pattern::Noise, 99);
            let engine = pool.engine(&name).unwrap();
            let got = engine.run1(&[&img.data, &k]).unwrap();
            let alg = match algorithm.as_str() {
                "twopass" => Algorithm::TwoPass,
                _ => Algorithm::SinglePassNoCopy,
            };
            let want = convolve_image(img, &k, alg, Variant::Simd).unwrap();
            let d = max_diff(&got, &want.data);
            assert!(d < 1e-4, "{name}: max diff {d}");
        }
    }

    #[test]
    fn full_image_artifacts_match_native_at_smallest_size() {
        let pool = pool();
        let k = pool.manifest().kernel_values.clone();
        let n = pool.manifest().full_sizes()[0];
        for (alg_name, alg) in
            [("twopass", Algorithm::TwoPass), ("singlepass", Algorithm::SinglePassNoCopy)]
        {
            let name = format!("{alg_name}_p3_{n}");
            let img = synth_image(3, n, n, Pattern::Checker, 5);
            let engine = pool.engine(&name).unwrap();
            let got = engine.run1(&[&img.data, &k]).unwrap();
            let want = convolve_image(img, &k, alg, Variant::Simd).unwrap();
            let d = max_diff(&got, &want.data);
            assert!(d < 1e-4, "{name}: {d}");
        }
    }

    #[test]
    fn agglomerated_artifact_matches_native_3rxc() {
        let pool = pool();
        let k = pool.manifest().kernel_values.clone();
        let n = pool.manifest().full_sizes()[0];
        let img = synth_image(3, n, n, Pattern::Noise, 6);
        let engine = pool.engine(&format!("twopass_agg_{n}")).unwrap();
        let got = engine.run1(&[&img.data, &k]).unwrap();
        let m = OpenMpModel::new(2);
        let want =
            convolve_parallel(&m, &img, &k, Algorithm::TwoPass, Variant::Simd, Layout::Agglomerated)
                .unwrap();
        let d = max_diff(&got, &want.data);
        assert!(d < 1e-4, "agglomerated PJRT vs native 3RxC: {d}");
    }

    #[test]
    fn tile_artifacts_stitch_to_full_plane() {
        // schedule a full plane through the halo'd vertical tile artifact the
        // way the execution models would, and compare against a native sweep
        let pool = pool();
        let k = pool.manifest().kernel_values.clone();
        let (name, th, cols, halo) = {
            let tiles = pool.manifest().by_role("tile");
            let vert = tiles.iter().find(|t| t.variant == "vert").expect("vert tile");
            (
                vert.name.clone(),
                vert.meta_usize("tile_rows").unwrap(),
                vert.meta_usize("cols").unwrap(),
                vert.meta_usize("halo").unwrap(),
            )
        };

        let rows = th * 3 + 2 * halo; // three tiles of valid output
        let plane = synth_image(1, rows, cols, Pattern::Noise, 7);
        let engine = pool.engine(&name).unwrap();

        let mut stitched: Vec<f32> = Vec::new();
        for t in 0..3 {
            let r0 = t * th;
            let slab = &plane.plane(0)[r0 * cols..(r0 + th + 2 * halo) * cols];
            stitched.extend(engine.run1(&[slab, &k]).unwrap());
        }
        assert_eq!(stitched.len(), 3 * th * cols);

        // native vertical sweep (writes interior rows and columns of dst)
        let k5: [f32; 5] = k.clone().try_into().unwrap();
        let src = plane.plane(0).to_vec();
        let mut dst = src.clone();
        phi_conv::conv::band::vert_band_scalar(&src, &mut dst, rows, cols, &k5, 0, rows);
        // stitched row r corresponds to plane row r + halo; compare interior
        // columns (the native band leaves border columns untouched).
        for r in 0..3 * th {
            for j in halo..cols - halo {
                let g = stitched[r * cols + j];
                let w = dst[(r + halo) * cols + j];
                let d = (g - w).abs();
                assert!(d < 1e-4, "row {r} col {j}: {d}");
            }
        }
    }

    #[test]
    fn pyramid_artifact_levels_match_native() {
        let pool = pool();
        let k = pool.manifest().kernel_values.clone();
        let (name, n) = {
            let entry = pool.manifest().by_role("pyramid")[0];
            (entry.name.clone(), entry.meta_usize("rows").unwrap())
        };
        let img = synth_image(3, n, n, Pattern::Disc, 8);
        let engine = pool.engine(&name).unwrap();
        let outs = engine.run(&[&img.data, &k]).unwrap();
        assert_eq!(outs.len(), 3);

        // level 1 = blur(level 0) decimated
        let blurred = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        let mut want1 = PlanarImage::zeros(3, n / 2, n / 2);
        for p in 0..3 {
            for i in 0..n / 2 {
                for j in 0..n / 2 {
                    want1.set(p, i, j, blurred.get(p, 2 * i, 2 * j));
                }
            }
        }
        let d = max_diff(&outs[1], &want1.data);
        assert!(d < 1e-4, "pyramid level 1 vs native: {d}");
        assert_eq!(outs[0].len(), 3 * n * n);
        assert_eq!(outs[2].len(), 3 * (n / 4) * (n / 4));
    }

    #[test]
    fn actor_handle_serves_from_other_threads() {
        let handle = PjrtHandle::spawn(default_artifacts_dir()).unwrap();
        let pool = pool();
        let k = pool.manifest().kernel_values.clone();
        let n = pool.manifest().full_sizes()[0];
        let name = format!("twopass_p3_{n}");
        let img = synth_image(3, n, n, Pattern::Noise, 9);
        let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();

        let mut joins = vec![];
        for _ in 0..3 {
            let h = handle.clone();
            let name = name.clone();
            let data = img.data.clone();
            let k = k.clone();
            let want = want.data.clone();
            joins.push(std::thread::spawn(move || {
                let got = h.run1(&name, vec![data, k]).unwrap();
                assert!(max_diff(&got, &want) < 1e-4);
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        handle.shutdown();
    }

    #[test]
    fn engine_rejects_wrong_shapes() {
        let pool = pool();
        let n = pool.manifest().full_sizes()[0];
        let engine = pool.engine(&format!("twopass_p3_{n}")).unwrap();
        let too_small = vec![0f32; 10];
        let k = pool.manifest().kernel_values.clone();
        assert!(engine.run(&[&too_small, &k]).is_err());
        assert!(engine.run(&[&too_small]).is_err());
    }
}
