//! Golden-exhibit tests: regenerate the paper's simulated Table 1 /
//! Table 2 rows through `harness`/`phisim` and pin the qualitative
//! invariants the paper reports, so a phisim regression is caught by
//! `cargo test` rather than by eyeballing bench output.

use phi_conv::conv::{Algorithm, Variant};
use phi_conv::harness;
use phi_conv::models::Layout;
use phi_conv::phisim::{simulate, Calibration, Estimate, PhiMachine, SimRun, SimWorkload};

fn sim(w: &SimWorkload, run: &SimRun) -> Estimate {
    simulate(&PhiMachine::default(), &Calibration::default(), w, run)
}

const PAPER_SIZES: [usize; 6] = [1152, 1728, 2592, 3888, 5832, 8748];

#[test]
fn every_simulated_exhibit_regenerates() {
    for exhibit in ["fig1", "fig2", "fig3", "fig4", "table1", "table2", "threads", "all"] {
        let tables = harness::simulated(exhibit).unwrap();
        assert!(!tables.is_empty(), "{exhibit}");
        for t in &tables {
            assert!(t.n_rows() >= 3, "{exhibit}: {} rows", t.n_rows());
            let txt = t.to_text();
            assert!(txt.len() > 80, "{exhibit} renders");
            // every rendering stays paste-able in all three formats
            assert!(t.to_markdown().contains('|'));
            assert!(t.to_csv().contains(','));
        }
    }
    assert!(harness::simulated("not-an-exhibit").is_err());
}

#[test]
fn simulated_table1_has_paper_shape() {
    let t = &harness::simulated("table1").unwrap()[0];
    // one row per paper size, sim and paper value side by side
    assert_eq!(t.n_rows(), PAPER_SIZES.len());
    let txt = t.to_text();
    for size in PAPER_SIZES {
        assert!(txt.contains(&format!("{size}x{size}")), "missing {size} row");
    }
    assert!(txt.contains('|'), "sim | paper cells");
}

#[test]
fn simulated_table2_has_paper_shape() {
    let t = &harness::simulated("table2").unwrap()[0];
    assert_eq!(t.n_rows(), PAPER_SIZES.len());
    let txt = t.to_text();
    assert!(txt.contains("GPRM-total"));
    assert!(txt.contains("OpenCL-compute"));
}

/// The paper's chosen baseline (section 5.2): at the 5×5 kernel the
/// separable two-pass beats the unrolled single-pass on every size,
/// sequentially and under OpenMP — the reason Opt-3/4 exist at all.
#[test]
fn twopass_beats_singlepass_at_5x5() {
    for size in PAPER_SIZES {
        for variant in [Variant::Scalar, Variant::Simd] {
            let tp = sim(
                &SimWorkload::paper(size, Algorithm::TwoPass, variant),
                &SimRun::sequential(),
            )
            .total_ms();
            let sp = sim(
                &SimWorkload::paper(size, Algorithm::SinglePassCopyBack, variant),
                &SimRun::sequential(),
            )
            .total_ms();
            assert!(
                tp < sp,
                "{size} {variant:?}: sequential two-pass {tp:.2}ms !< single-pass {sp:.2}ms"
            );
            let tp_par = sim(
                &SimWorkload::paper(size, Algorithm::TwoPass, variant),
                &SimRun::openmp(100),
            )
            .total_ms();
            let sp_par = sim(
                &SimWorkload::paper(size, Algorithm::SinglePassCopyBack, variant),
                &SimRun::openmp(100),
            )
            .total_ms();
            assert!(
                tp_par < sp_par,
                "{size} {variant:?}: parallel two-pass {tp_par:.2}ms !< single-pass {sp_par:.2}ms"
            );
        }
    }
}

/// Speedup is monotone in the thread count up to the paper's operating
/// point. Past bandwidth saturation the busy term plateaus while the
/// per-thread dispatch overhead keeps growing, so the smallest image can
/// give back a few percent between 50 and 100 threads — the invariant is
/// "never falls by more than 10%, and strictly gains while unsaturated".
#[test]
fn openmp_speedup_monotone_in_threads() {
    for size in PAPER_SIZES {
        let w = SimWorkload::paper(size, Algorithm::TwoPass, Variant::Simd);
        let base = sim(&w, &SimRun::openmp(1)).total_ms();
        let mut prev_speedup = 1.0;
        for threads in [2usize, 4, 10, 25, 50, 100] {
            let speedup = base / sim(&w, &SimRun::openmp(threads)).total_ms();
            assert!(
                speedup >= prev_speedup * 0.90,
                "{size}: speedup fell {prev_speedup:.2} -> {speedup:.2} at {threads} threads"
            );
            if threads <= 10 {
                // pre-saturation: each doubling must strictly pay
                assert!(
                    speedup > prev_speedup * 1.2,
                    "{size}: only {prev_speedup:.2} -> {speedup:.2} at {threads} threads"
                );
            }
            prev_speedup = speedup;
        }
        // and parallelism must actually pay: ≥ 4x by 100 threads
        assert!(prev_speedup > 4.0, "{size}: only {prev_speedup:.1}x at 100 threads");
    }
}

/// Table 2's headline structure: GPRM is overhead-dominated at the small
/// sizes (loses to OpenMP) and the 3R×C agglomeration flips the ordering
/// at the largest image — the paper's central finding.
#[test]
fn gprm_crossover_structure_preserved() {
    let small = SimWorkload::paper(1152, Algorithm::TwoPass, Variant::Simd);
    let omp_small = sim(&small, &SimRun::openmp(100)).total_ms();
    let gprm_small = sim(&small, &SimRun::gprm(100, Layout::PerPlane)).total_ms();
    assert!(gprm_small > omp_small, "GPRM must lose at 1152 RxC");

    let large = SimWorkload::paper(8748, Algorithm::TwoPass, Variant::Simd);
    let omp_large = sim(&large, &SimRun::openmp(100)).total_ms();
    let gprm_agg = sim(&large, &SimRun::gprm(100, Layout::Agglomerated)).total_ms();
    assert!(gprm_agg < omp_large, "GPRM 3RxC must win at 8748");

    // the overhead split itself: agglomeration divides dispatches by the
    // plane count (3), exactly
    let rxc = sim(&large, &SimRun::gprm(100, Layout::PerPlane)).overhead_ms;
    let agg = sim(&large, &SimRun::gprm(100, Layout::Agglomerated)).overhead_ms;
    assert!((rxc / agg - 3.0).abs() < 1e-9, "overhead ratio {}", rxc / agg);
}

/// The vectorisation columns of Table 1: SIMD beats no-vec for every
/// model at every size, and the sequential SIMD gain exceeds the
/// 100-thread gain (bandwidth saturation, paper 8.6x vs 4.2x).
#[test]
fn vectorisation_gains_match_paper_structure() {
    for size in PAPER_SIZES {
        for run in [SimRun::openmp(100), SimRun::opencl(), SimRun::gprm(100, Layout::PerPlane)] {
            let novec =
                sim(&SimWorkload::paper(size, Algorithm::TwoPass, Variant::Scalar), &run).total_ms();
            let simd =
                sim(&SimWorkload::paper(size, Algorithm::TwoPass, Variant::Simd), &run).total_ms();
            assert!(simd < novec, "{size} {:?}: SIMD {simd:.2} !< no-vec {novec:.2}", run.model);
        }
    }
    let seq_gain = sim(
        &SimWorkload::paper(2592, Algorithm::TwoPass, Variant::Scalar),
        &SimRun::sequential(),
    )
    .total_ms()
        / sim(&SimWorkload::paper(2592, Algorithm::TwoPass, Variant::Simd), &SimRun::sequential())
            .total_ms();
    let par_gain = sim(
        &SimWorkload::paper(2592, Algorithm::TwoPass, Variant::Scalar),
        &SimRun::openmp(100),
    )
    .total_ms()
        / sim(&SimWorkload::paper(2592, Algorithm::TwoPass, Variant::Simd), &SimRun::openmp(100))
            .total_ms();
    assert!(seq_gain > par_gain, "sequential gain {seq_gain:.1} !> parallel {par_gain:.1}");
}

/// Measured exhibits run end-to-end too (tiny sizes so the suite stays
/// fast): the harness that feeds `cargo bench` must not rot.
#[test]
fn measured_exhibits_run_at_tiny_sizes() {
    let cfg = phi_conv::config::RunConfig {
        sizes: vec![32, 48],
        reps: 1,
        warmup: 0,
        threads: 2,
        ..Default::default()
    };
    for exhibit in ["fig1", "table1", "threads"] {
        let tables = harness::run_measured(exhibit, &cfg).unwrap();
        assert!(!tables.is_empty(), "{exhibit}");
        for t in &tables {
            assert!(t.n_rows() >= 2, "{exhibit}");
        }
    }
    assert!(harness::run_measured("bogus", &cfg).is_err());
}
