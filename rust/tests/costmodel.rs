//! Integration: the learned cost model end-to-end through the public
//! API — fit/predict over synthetic truth, persistence (bitwise
//! save/load), the `TuningTable` predictive tier, coordinator admission
//! serving never-swept shapes from predictions, and the low-R² route
//! back to empirical sweeping.

use std::path::PathBuf;

use phi_conv::autotune::{default_candidates, Candidate, PlanDecision, TuningTable};
use phi_conv::config::RunConfig;
use phi_conv::conv::{convolve_image, Algorithm, Variant};
use phi_conv::coordinator::{Backend, ConvRequest, Coordinator, RoutePolicy};
use phi_conv::costmodel::{dispatch_units, CostModel, Sample};
use phi_conv::image::{gaussian_kernel, synth_image, Pattern};
use phi_conv::models::TileSpec;
use phi_conv::util::json::Json;
use phi_conv::util::prng::Prng;

/// Noise-free synthetic truth with a strict candidate ordering:
/// fused+tiled (1×) < unfused+tiled (2×) < fused+untiled (3×) <
/// unfused+untiled (4×), each over an affine base in the real features.
fn truth_ms(pixels: f64, width: f64, units: f64, fused: bool, tiled: bool) -> f64 {
    let base = 0.2 + 1.5e-6 * pixels + 2.0e-7 * pixels * width + 1e-3 * units;
    let mult = match (fused, tiled) {
        (false, false) => 4.0,
        (true, false) => 3.0,
        (false, true) => 2.0,
        (true, true) => 1.0,
    };
    base * mult
}

/// A training grid disjoint from every probe shape the tests use:
/// 6 sizes × 3 widths × 3 tiles × fused/unfused per execution model.
fn synthetic_samples(model: &str, workers: usize) -> Vec<Sample> {
    let tiles = [None, Some(TileSpec::new(16, usize::MAX)), Some(TileSpec::new(32, 32))];
    let mut out = Vec::new();
    for size in [48usize, 64, 96, 128, 192, 256] {
        for width in [3usize, 5, 7] {
            for tile in tiles {
                for fused in [false, true] {
                    let units = dispatch_units(size, size, tile, workers);
                    let pixels = (3 * size * size) as f64;
                    out.push(Sample {
                        model: model.to_string(),
                        class: "separable".to_string(),
                        planes: 3,
                        rows: size,
                        cols: size,
                        kernel_width: width,
                        tile,
                        fused,
                        agglomeration: 1,
                        units,
                        workers,
                        ms: truth_ms(pixels, width as f64, units as f64, fused, tile.is_some()),
                        reps: 3,
                        warmup: 1,
                    });
                }
            }
        }
    }
    out
}

fn temp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("phi_conv_costmodel_{tag}_{}.json", std::process::id()))
}

#[test]
fn untiled_baseline_leads_every_candidate_set() {
    for rows in [8usize, 16, 32, 64, 128, 256, 512, 1152] {
        for gprm in [false, true] {
            let cands = default_candidates(rows, gprm);
            assert_eq!(
                cands[0],
                Candidate::untiled(),
                "rows={rows} gprm={gprm}: the untiled baseline must be candidate 0"
            );
        }
    }
}

#[test]
fn fit_recovers_truth_and_chooses_fused_tiled() {
    let cm = CostModel::fit(synthetic_samples("OpenMP", 4), 0.8);
    assert_eq!(cm.groups().len(), 4);
    assert_eq!(cm.usable_groups(), 4, "noise-free truth must fit every group");

    // 100×100 is not in the training grid
    let p = cm.choose("OpenMP", 3, 100, 100, 5, 4).expect("usable model predicts");
    assert!(p.candidate.fused && p.candidate.tile.is_some(), "truth makes fused+tiled cheapest");
    assert!(p.ms <= p.baseline_ms, "winner never predicted worse than the untiled baseline");
    assert!(p.baseline_ms > 0.0 && p.ms.is_finite());
}

#[test]
fn saved_then_loaded_model_predicts_bitwise_identically() {
    let cm = CostModel::fit(synthetic_samples("GPRM", 4), 0.8);
    let path = temp_path("roundtrip");
    cm.save(&path).unwrap();
    let loaded = CostModel::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(loaded.samples(), cm.samples(), "raw samples travel with the fit");
    for g in cm.groups() {
        for (rows, cols, width) in [(100usize, 100usize, 5usize), (300, 200, 7), (60, 60, 3)] {
            let tile = if g.tiled { Some(TileSpec::new(16, usize::MAX)) } else { None };
            let a = cm.predict_ms(&g.model, g.fused, tile, 3, rows, cols, width, 4);
            let b = loaded.predict_ms(&g.model, g.fused, tile, 3, rows, cols, width, 4);
            assert_eq!(
                a.map(f64::to_bits),
                b.map(f64::to_bits),
                "{} fused={} tiled={} at {rows}x{cols} w{width}",
                g.model,
                g.fused,
                g.tiled
            );
        }
    }
    assert_eq!(
        cm.choose("GPRM", 3, 144, 144, 5, 4),
        loaded.choose("GPRM", 3, 144, 144, 5, 4),
        "the decision itself survives persistence"
    );
}

#[test]
fn null_coefficients_load_as_invalid_model_never_zero() {
    let text = r#"{"bench":"costmodel","r2_min":0.8,
        "features":["pixels","width","pixels_width","units"],
        "samples":[],
        "models":[{"model":"OpenMP","fused":false,"tiled":false,"n_samples":9,
                   "coeffs":null,"r2":null,"n":null}]}"#;
    let cm = CostModel::from_json(&Json::parse(text).unwrap()).unwrap();
    assert_eq!(cm.groups().len(), 1);
    assert!(cm.groups()[0].fit.is_none(), "null coeffs = invalid model, not zeros");
    assert!(cm.predict_ms("OpenMP", false, None, 3, 64, 64, 5, 4).is_none());
    assert!(cm.choose("OpenMP", 3, 64, 64, 5, 4).is_none(), "invalid baseline group → sweep");
}

#[test]
fn coordinator_serves_unseen_shape_from_prediction() {
    let cfg = RunConfig { threads: 2, reps: 1, warmup: 0, ..Default::default() };
    let mut coord =
        Coordinator::new(&cfg, RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
    let cm = CostModel::fit(synthetic_samples("OpenMP", cfg.threads), 0.8);
    assert_eq!(cm.usable_groups(), 4);
    let mut tuning = TuningTable::new();
    tuning.set_cost_model(cm);
    coord.set_tuning(tuning);

    // 3×100×100 was never swept and never trained on: the prediction
    // decides tile+fusion at admission, no warm-up sweep, and the pixels
    // still match the oracle.
    let img = synth_image(3, 100, 100, Pattern::Noise, 77);
    let k = gaussian_kernel(cfg.kernel_width, cfg.sigma);
    let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
    let resp = coord.serve(ConvRequest::new(1, img)).unwrap();
    assert!(
        resp.image.max_abs_diff(&want) < 1e-5,
        "predicted tile/fusion must not change the pixels"
    );
    let st = coord.stats();
    assert_eq!(
        (st.plans_predicted, st.plans_swept, st.plans_default),
        (1, 0, 0),
        "exactly one predicted plan decision"
    );
    assert_eq!((st.served, st.errors), (1, 0));
}

#[test]
fn low_r2_fit_falls_back_to_empirical_sweeping() {
    // pure-noise targets: every group fits (full rank) but explains
    // nothing, so the R² gate rejects them all
    let mut rng = Prng::new(0xf17_ba11);
    let mut noisy = synthetic_samples("OpenMP", 2);
    for s in &mut noisy {
        let u = (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
        s.ms = 1.0 + 100.0 * u;
    }
    let cm = CostModel::fit(noisy, 0.8);
    assert_eq!(cm.usable_groups(), 0, "noise must not pass the R² gate");

    let mut table = TuningTable::new();
    table.set_cost_model(cm);
    assert!(
        table.choose("OpenMP", 3, 24, 24, 5, 2).is_none(),
        "a low-R² model declines to predict — the caller sweeps"
    );

    // ...and after the empirical sweep the same query hits the exact tier
    let cfg = RunConfig { threads: 2, reps: 1, warmup: 0, sizes: vec![24], ..Default::default() };
    phi_conv::autotune::sweep_shape(&cfg, 24, &mut table).unwrap();
    match table.choose("OpenMP", 3, 24, 24, 5, 2) {
        Some(PlanDecision::Swept(t)) => {
            assert!(t.ms <= t.baseline_ms, "swept winner beats or equals the untiled baseline")
        }
        other => panic!("expected a swept decision after the fallback sweep, got {other:?}"),
    }
}

#[test]
fn real_sweep_samples_train_a_model_end_to_end() {
    // a tiny real sweep (timing noise and all) must produce
    // self-describing samples and fit without panicking; usability is
    // not asserted — real timings on a loaded CI runner may legitimately
    // fail the gate, which is exactly the fallback path.
    let cfg = RunConfig { threads: 2, reps: 1, warmup: 0, ..Default::default() };
    let mut table = TuningTable::new();
    let mut samples = Vec::new();
    for size in [24usize, 32] {
        phi_conv::autotune::sweep_shape_sampled(&cfg, size, &mut table, &mut samples).unwrap();
    }
    assert!(!samples.is_empty());
    for s in &samples {
        assert_eq!((s.reps, s.warmup), (cfg.reps, cfg.warmup), "samples carry their protocol");
        assert!(s.workers >= 1 && s.units >= 1 && s.ms >= 0.0);
        assert_eq!(s.units, dispatch_units(s.rows, s.cols, s.tile, s.workers));
    }
    // the sweep measures every kernel class, so the fitted model can
    // place the direct-vs-fft crossover
    for class in ["separable", "direct2d", "fft"] {
        assert!(samples.iter().any(|s| s.class == class), "class {class} sampled");
    }
    let cm = CostModel::fit(samples, cfg.r2_min);
    assert_eq!(
        cm.groups().iter().map(|g| g.n_samples).sum::<usize>(),
        cm.samples().len(),
        "every sample lands in exactly one group"
    );
}
