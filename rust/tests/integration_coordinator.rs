//! Integration: the coordinator end-to-end — routing, queueing, stats
//! and oracle-verified responses across the native backends (always on),
//! plus the PJRT-backed paths when built with `--features pjrt` and real
//! artifacts.

use phi_conv::config::RunConfig;
use phi_conv::conv::{convolve_image, Algorithm, Variant};
use phi_conv::coordinator::{Backend, ConvRequest, Coordinator, RoutePolicy};
use phi_conv::image::{gaussian_kernel, synth_image, Pattern};

fn cfg() -> RunConfig {
    RunConfig { threads: 2, reps: 1, warmup: 0, ..Default::default() }
}

#[test]
fn every_native_backend_matches_the_oracle() {
    let coord = Coordinator::new(&cfg(), RoutePolicy::RoundRobin, 2, false).unwrap();
    let img = synth_image(3, 48, 40, Pattern::Noise, 11);
    let k = gaussian_kernel(5, 1.0);
    let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
    for backend in [Backend::NativeOpenMp, Backend::NativeOpenCl, Backend::NativeGprm] {
        let resp = coord
            .serve(ConvRequest::new(1, img.clone()).with_backend(backend))
            .unwrap();
        assert_eq!(resp.backend, backend);
        assert_eq!(resp.image, want, "{backend:?} differs from oracle");
    }
    assert_eq!(coord.stats().served, 3);
}

#[test]
fn algorithm_and_variant_respected_end_to_end() {
    let coord =
        Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
    let img = synth_image(3, 36, 36, Pattern::Disc, 12);
    let k = gaussian_kernel(5, 1.0);
    for (alg, variant) in [
        (Algorithm::SinglePassNoCopy, Variant::Simd),
        (Algorithm::SinglePassCopyBack, Variant::Scalar),
        (Algorithm::TwoPass, Variant::Scalar),
    ] {
        let want = convolve_image(img.clone(), &k, alg, variant).unwrap();
        let resp = coord
            .serve(ConvRequest::new(1, img.clone()).with_algorithm(alg).with_variant(variant))
            .unwrap();
        assert_eq!(resp.image, want, "{alg:?} {variant:?}");
    }
}

// (Adaptive small/large routing is covered by the coordinator's own
// unit test `adaptive_policy_routes_by_size` in src/coordinator/server.rs.)

#[test]
fn failed_requests_are_counted_not_fatal() {
    // TwoPass × Naive is rejected by the engines (the paper's naive rung
    // is single-pass only); the coordinator must return the error to the
    // caller, count it, and keep serving.
    let coord =
        Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
    let img = synth_image(3, 24, 24, Pattern::Noise, 3);
    let err = coord
        .serve(ConvRequest::new(1, img.clone()).with_algorithm(Algorithm::TwoPass).with_variant(Variant::Naive));
    assert!(err.is_err());
    let ok = coord.serve(ConvRequest::new(2, img));
    assert!(ok.is_ok());
    let st = coord.stats();
    assert_eq!((st.errors, st.served), (1, 1));
}

#[test]
fn throughput_accounting_consistent() {
    let coord = Coordinator::new(&cfg(), RoutePolicy::paper_default(), 2, false).unwrap();
    let img = synth_image(3, 48, 48, Pattern::Noise, 6);
    let rxs: Vec<_> =
        (0..10).map(|i| coord.submit(ConvRequest::new(i, img.clone())).unwrap()).collect();
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        assert!(resp.service_ms >= 0.0 && resp.queue_ms >= 0.0);
        assert!(resp.latency_ms() >= resp.service_ms);
    }
    let st = coord.stats();
    assert_eq!(st.served, 10);
    assert_eq!(st.queue_ms.len(), 10);
    let per_backend: usize = st.service_ms.values().map(|s| s.len()).sum();
    assert_eq!(per_backend, 10);
}

#[cfg(not(feature = "pjrt"))]
#[test]
fn pjrt_backend_unavailable_without_the_feature() {
    // with_pjrt = true must fail with the gate (or the missing manifest),
    // never panic — the CLI surfaces this as a plain error
    let err = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::Pjrt), 1, true);
    assert!(err.is_err());
}

// ---------------------------------------------------------------------------
// `--features pjrt` + real artifacts: the PJRT-backed serving paths.
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod with_pjrt {
    use super::*;
    use phi_conv::models::Layout;

    fn smallest_artifact_size(cfg: &RunConfig) -> usize {
        phi_conv::runtime::Manifest::load(&cfg.artifacts_dir)
            .expect("run `make artifacts`")
            .full_sizes()[0]
    }

    #[test]
    fn pjrt_request_matches_oracle() {
        let cfg = cfg();
        let n = smallest_artifact_size(&cfg);
        let coord = Coordinator::new(&cfg, RoutePolicy::Fixed(Backend::Pjrt), 1, true).unwrap();
        let img = synth_image(3, n, n, Pattern::Noise, 1);
        let k = gaussian_kernel(5, 1.0);
        let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        let resp = coord.serve(ConvRequest::new(1, img)).unwrap();
        assert_eq!(resp.backend, Backend::Pjrt);
        let d = resp
            .image
            .data
            .iter()
            .zip(&want.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(d < 1e-4, "PJRT-served response differs from oracle: {d}");
    }

    #[test]
    fn singlepass_requests_via_pjrt() {
        let cfg = cfg();
        let n = smallest_artifact_size(&cfg);
        let coord = Coordinator::new(&cfg, RoutePolicy::Fixed(Backend::Pjrt), 1, true).unwrap();
        let img = synth_image(3, n, n, Pattern::Disc, 2);
        let k = gaussian_kernel(5, 1.0);
        let want =
            convolve_image(img.clone(), &k, Algorithm::SinglePassNoCopy, Variant::Simd).unwrap();
        let resp = coord
            .serve(ConvRequest::new(1, img).with_algorithm(Algorithm::SinglePassNoCopy))
            .unwrap();
        assert_eq!(resp.backend, Backend::Pjrt);
        assert!(resp.image.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn mixed_backends_all_agree() {
        let cfg = cfg();
        let n = smallest_artifact_size(&cfg);
        let coord = Coordinator::new(&cfg, RoutePolicy::RoundRobin, 2, true).unwrap();
        let img = synth_image(3, n, n, Pattern::Checker, 3);
        let k = gaussian_kernel(5, 1.0);
        let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        for backend in
            [Backend::NativeOpenMp, Backend::NativeOpenCl, Backend::NativeGprm, Backend::Pjrt]
        {
            let resp = coord
                .serve(ConvRequest::new(1, img.clone()).with_backend(backend))
                .unwrap();
            assert!(
                resp.image.max_abs_diff(&want) < 1e-4,
                "{backend:?} differs from oracle"
            );
        }
        assert_eq!(coord.stats().served, 4);
    }

    #[test]
    fn warm_pjrt_compiles_artifacts() {
        let cfg = cfg();
        let n = smallest_artifact_size(&cfg);
        let coord = Coordinator::new(&cfg, RoutePolicy::paper_default(), 1, true).unwrap();
        let warmed = coord.warm_pjrt(3, &[n]).unwrap();
        assert!(warmed.len() >= 2, "expected twopass+singlepass+agg, got {warmed:?}");
        for (name, ms) in &warmed {
            assert!(*ms > 0.0, "{name} compile time");
        }
        // warm again: cached, near-zero compile time reported for reuse
        let again = coord.warm_pjrt(3, &[n]).unwrap();
        assert_eq!(again.len(), warmed.len());
    }

    #[test]
    fn agglomerated_layout_request_via_pjrt() {
        let cfg = cfg();
        let n = smallest_artifact_size(&cfg);
        let coord = Coordinator::new(&cfg, RoutePolicy::Fixed(Backend::Pjrt), 1, true).unwrap();
        let img = synth_image(3, n, n, Pattern::Noise, 4);
        let resp = coord
            .serve(ConvRequest::new(1, img.clone()).with_layout(Layout::Agglomerated))
            .unwrap();
        assert_eq!(resp.backend, Backend::Pjrt);
        assert_eq!(resp.layout, Layout::Agglomerated);
        // seams aside, the interior matches per-plane convolution
        let k = gaussian_kernel(5, 1.0);
        let want = convolve_image(img, &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        let mut max_d = 0f32;
        for p in 0..3 {
            for i in 0..n {
                for j in 4..n - 4 {
                    max_d = max_d.max((resp.image.get(p, i, j) - want.get(p, i, j)).abs());
                }
            }
        }
        assert!(max_d < 1e-4, "interior diff {max_d}");
    }

    #[test]
    fn error_responses_counted_not_fatal() {
        // a non-square image cannot be served by PJRT and falls back
        let cfg = cfg();
        let coord = Coordinator::new(&cfg, RoutePolicy::Fixed(Backend::Pjrt), 1, true).unwrap();
        let img = synth_image(3, 30, 20, Pattern::Noise, 5); // non-square
        let resp = coord.serve(ConvRequest::new(1, img)).unwrap();
        assert_ne!(resp.backend, Backend::Pjrt);
        assert_eq!(coord.stats().pjrt_fallbacks, 1);
        assert_eq!(coord.stats().errors, 0);
    }
}
