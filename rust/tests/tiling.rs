//! Tier-1 tiling battery (wired into `scripts/verify.sh`):
//!
//! * **cover-exactness** — `dispatch` and `dispatch2d` visit every
//!   index/tile of their range exactly once under every model, observed
//!   through an atomic bitmap oracle (each worker marks cells with
//!   relaxed atomics; the assertion after the call also witnesses the
//!   implicit barrier — a missing barrier would race the final check),
//!   including degenerate shapes (n = 0, n < workers, 1×N, N×1, tiles
//!   larger than the image);
//! * **differential equivalence** — tiled and untiled plans produce the
//!   same pixels (≤ 1e-6) across kernel widths {3, 5, 7, 9}, both
//!   layouts and all three models, seeded via `util::prng`;
//! * **GPRM stress** — deterministic seeded 10k-tile bursts under both
//!   steal policies and several agglomeration factors: no lost or
//!   double-executed tiles.
//!
//! Worker counts honour `PHI_THREADS` (the CI matrix runs 1 and 4).

use std::sync::atomic::{AtomicU32, Ordering};

use phi_conv::image::{synth_image, Pattern};
use phi_conv::models::{
    test_threads, ExecutionModel, GprmModel, Layout, OpenClModel, OpenMpModel, Schedule,
    StealPolicy, TileSpec,
};
use phi_conv::plan::{ConvPlan, KernelSpec, ScratchArena};
use phi_conv::util::prng::Prng;

fn threads() -> usize {
    test_threads(4)
}

fn all_models() -> Vec<Box<dyn ExecutionModel>> {
    let t = threads();
    vec![
        Box::new(OpenMpModel::new(t)),
        Box::new(OpenMpModel::with_schedule(t, Schedule::Dynamic(2))),
        Box::new(OpenMpModel::with_schedule(t, Schedule::Guided(1))),
        Box::new(OpenClModel::new(t, 3)),
        Box::new(OpenClModel::new(t, 1)),
        Box::new(GprmModel::new(t, 13)),
        Box::new(GprmModel::with_policy(t, 50, StealPolicy::Random)),
        Box::new(GprmModel::new(t, 7).with_agglomeration(5)),
    ]
}

/// Atomic bitmap oracle: one relaxed counter per cell, incremented by
/// whichever worker visits it; exactly-once is asserted after the
/// barrier implied by the dispatch call returning.
struct Bitmap {
    cells: Vec<AtomicU32>,
    cols: usize,
}

impl Bitmap {
    fn new(rows: usize, cols: usize) -> Self {
        Self { cells: (0..rows * cols).map(|_| AtomicU32::new(0)).collect(), cols }
    }

    fn mark(&self, i: usize, j: usize) {
        self.cells[i * self.cols + j].fetch_add(1, Ordering::Relaxed);
    }

    fn assert_exactly_once(&self, context: &str) {
        for (ix, c) in self.cells.iter().enumerate() {
            let n = c.load(Ordering::SeqCst);
            assert_eq!(n, 1, "{context}: cell {ix} visited {n} times");
        }
    }
}

#[test]
fn dispatch_cover_exactness_all_models() {
    // 1-D contract: [0, n) covered exactly once, including n = 0 and
    // n < workers (models built once — each owns a worker pool)
    let models = all_models();
    for n in [0usize, 1, 3, 7, 100, 241] {
        for m in &models {
            let bitmap = Bitmap::new(1, n.max(1));
            let visited = AtomicU32::new(0);
            m.dispatch(n, &|a, b| {
                assert!(a < b && b <= n, "{}: bad range [{a}, {b}) of {n}", m.name());
                for j in a..b {
                    bitmap.mark(0, j);
                }
                visited.fetch_add(1, Ordering::Relaxed);
            });
            if n == 0 {
                assert_eq!(visited.load(Ordering::SeqCst), 0, "{}: n=0 must be a no-op", m.name());
                continue;
            }
            for j in 0..n {
                let c = bitmap.cells[j].load(Ordering::SeqCst);
                assert_eq!(c, 1, "{}: index {j} of {n} visited {c} times", m.name());
            }
        }
    }
}

#[test]
fn dispatch2d_cover_exactness_all_models() {
    // 2-D contract: every cell of the grid in exactly one tile, for
    // degenerate grids (empty, 1×N, N×1) and tiles larger than the image
    let shapes = [(0usize, 0usize), (0, 9), (9, 0), (1, 1), (1, 37), (37, 1), (24, 20), (61, 47)];
    let tiles = [
        TileSpec::new(1, 1),
        TileSpec::new(4, 4),
        TileSpec::new(7, 3),
        TileSpec::new(16, 64),
        TileSpec::new(1000, 1000),
    ];
    let models = all_models();
    for &(rows, cols) in &shapes {
        for &tile in &tiles {
            for m in &models {
                let bitmap = Bitmap::new(rows.max(1), cols.max(1));
                m.dispatch2d(rows, cols, tile, &|t| {
                    assert!(
                        t.r0 < t.r1 && t.r1 <= rows && t.c0 < t.c1 && t.c1 <= cols,
                        "{}: bad tile {t:?} in {rows}x{cols}",
                        m.name()
                    );
                    for i in t.r0..t.r1 {
                        for j in t.c0..t.c1 {
                            bitmap.mark(i, j);
                        }
                    }
                });
                if rows == 0 || cols == 0 {
                    // empty grid: the assert inside the job would have
                    // fired if any tile was produced
                    continue;
                }
                bitmap.assert_exactly_once(&format!(
                    "{} {rows}x{cols} tile {}",
                    m.name(),
                    tile.label()
                ));
            }
        }
    }
}

#[test]
fn tiled_equals_untiled_across_widths_layouts_models() {
    // differential suite: tiled plans bit-compare (≤ 1e-6) against the
    // untiled row-band plans, shapes and tiles drawn from a seeded PRNG
    let mut rng = Prng::new(0x711E_D1FF);
    let models: Vec<Box<dyn ExecutionModel>> = vec![
        Box::new(OpenMpModel::new(threads())),
        Box::new(OpenClModel::new(threads(), 3)),
        Box::new(GprmModel::new(threads(), 13).with_agglomeration(3)),
    ];
    for width in [3usize, 5, 7, 9] {
        for layout in [Layout::PerPlane, Layout::Agglomerated] {
            let rows = rng.range(24, 40);
            let cols = rng.range(24, 40);
            let image = synth_image(3, rows, cols, Pattern::Noise, width as u64);
            let tile = TileSpec::new(rng.range(1, 12), rng.range(1, 12));
            let untiled = ConvPlan::builder()
                .layout(layout)
                .kernel(KernelSpec::new(width, 1.0))
                .shape(3, rows, cols)
                .build()
                .unwrap();
            let tiled = ConvPlan::builder()
                .layout(layout)
                .kernel(KernelSpec::new(width, 1.0))
                .tile(tile)
                .shape(3, rows, cols)
                .build()
                .unwrap();
            let mut arena = ScratchArena::new();
            let want = untiled.execute(&image, &mut arena).unwrap();
            for m in &models {
                let got = tiled.execute_on(m.as_ref(), &image, &mut arena).unwrap();
                assert!(
                    got.max_abs_diff(&want) <= 1e-6,
                    "{} width {width} {layout:?} tile {} ({rows}x{cols})",
                    m.name(),
                    tile.label()
                );
            }
        }
    }
}

#[test]
fn gprm_stress_10k_tile_bursts() {
    // deterministic seeded bursts: a 200×50 grid of 1×1 tiles = 10_000
    // tiles per dispatch, repeated, under both steal policies and
    // several agglomeration factors — no lost or double-executed tiles
    let (rows, cols) = (200usize, 50usize);
    for policy in [StealPolicy::Ring, StealPolicy::Random] {
        for agglomeration in [1usize, 7, 64] {
            let m = GprmModel::with_policy(threads(), 64, policy).with_agglomeration(agglomeration);
            for burst in 0..3 {
                let bitmap = Bitmap::new(rows, cols);
                m.dispatch2d(rows, cols, TileSpec::new(1, 1), &|t| {
                    bitmap.mark(t.r0, t.c0);
                });
                bitmap.assert_exactly_once(&format!(
                    "{policy:?} agg={agglomeration} burst {burst}"
                ));
            }
        }
    }
}

#[test]
fn overhead_probe_samples_finite_and_counted() {
    // regression for the old hardcoded warmup: every empty-dispatch
    // overhead sample is finite and the summaries carry n > 0
    let t = threads();
    let models: Vec<Box<dyn ExecutionModel>> = vec![
        Box::new(OpenMpModel::new(t)),
        Box::new(OpenClModel::new(t, 16)),
        Box::new(GprmModel::new(t, 20)),
    ];
    for m in models {
        let s = m.overhead_probe(256, 4);
        assert_eq!(s.len(), 4, "{}", m.name());
        assert!(
            s.samples().iter().all(|v| v.is_finite() && *v >= 0.0),
            "{}: non-finite overhead sample",
            m.name()
        );
        let summary = s.summary();
        assert!(summary.starts_with("n=4"), "{}: {summary}", m.name());
        assert!(!summary.contains("inf") && !summary.contains("NaN"), "{}: {summary}", m.name());
        // explicit warmup pinning (what the harness passes from config)
        let s = m.overhead_probe_with(64, 0, 3);
        assert_eq!(s.len(), 3);
        // the tile-granular probe: finite at several agglomeration shapes
        let s = m.overhead_probe2d(64, 64, TileSpec::new(8, 8), 1, 3);
        assert_eq!(s.len(), 3);
        assert!(s.samples().iter().all(|v| v.is_finite() && *v >= 0.0));
    }
}
