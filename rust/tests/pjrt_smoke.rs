//! Smoke: load + execute one AOT artifact through PJRT and sanity-check
//! the numerics (full validation against native engines lives in
//! `integration_runtime.rs`).
//!
//! Requires `--features pjrt` plus real artifacts (`make artifacts`);
//! the default build compiles this target to an empty suite.
#![cfg(feature = "pjrt")]

use phi_conv::runtime::{manifest::default_artifacts_dir, EnginePool};

#[test]
fn horiz_tile_executes_and_smooths() {
    let pool = EnginePool::open(default_artifacts_dir()).expect("make artifacts first");
    let name = "horiz_tile_64x288";
    let engine = pool.engine(name).unwrap();
    assert_eq!(engine.inputs[0].shape, vec![64, 288]);

    // ramp input: horizontal Gaussian of a linear ramp is the same ramp
    // (interior), a strong analytic check.
    let mut img = vec![0f32; 64 * 288];
    for r in 0..64 {
        for c in 0..288 {
            img[r * 288 + c] = c as f32;
        }
    }
    let k = pool.manifest().kernel_values.clone();
    let out = engine.run1(&[&img, &k]).unwrap();
    assert_eq!(out.len(), 64 * 284);
    // valid output col j corresponds to input col j+2; ramp is preserved
    for r in [0usize, 31, 63] {
        for j in [0usize, 100, 283] {
            let got = out[r * 284 + j];
            let want = (j + 2) as f32;
            assert!(
                (got - want).abs() < 1e-3,
                "r={r} j={j}: got {got}, want {want}"
            );
        }
    }
}

#[test]
fn pyramid_multi_output() {
    let pool = EnginePool::open(default_artifacts_dir()).unwrap();
    let engine = pool.engine("pyramid_1152").unwrap();
    assert_eq!(engine.outputs.len(), 3);
    let img = vec![1.5f32; 3 * 1152 * 1152];
    let k = pool.manifest().kernel_values.clone();
    let outs = engine.run(&[&img, &k]).unwrap();
    assert_eq!(outs[0].len(), 3 * 1152 * 1152);
    assert_eq!(outs[1].len(), 3 * 576 * 576);
    assert_eq!(outs[2].len(), 3 * 288 * 288);
    // constant image is a fixed point of normalised blur + decimate
    for o in &outs {
        for &v in o.iter().step_by(1001) {
            assert!((v - 1.5).abs() < 1e-4, "{v}");
        }
    }
}
