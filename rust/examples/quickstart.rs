//! Quickstart: convolve one 3-plane image with the public API.
//!
//! Shows the three entry points a user starts with:
//!   1. sequential native engines   (`conv::convolve_image`)
//!   2. a parallel execution model  (`models::convolve_parallel`)
//!   3. the AOT/PJRT path           (`runtime::EnginePool`)
//!
//! Run: `cargo run --offline --release --example quickstart`

use phi_conv::Result;

use phi_conv::conv::{convolve_image, Algorithm, Variant};
use phi_conv::image::{gaussian_kernel, synth_image, write_pgm, Pattern};
use phi_conv::models::{convolve_parallel, ExecutionModel, Layout, OpenMpModel};
use phi_conv::runtime::{manifest::default_artifacts_dir, EnginePool};

fn main() -> Result<()> {
    let size = 288;
    let img = synth_image(3, size, size, Pattern::Disc, 7);
    let k = gaussian_kernel(5, 1.0);
    println!("input: 3 planes of {size}x{size} f32 ('disc' pattern)");

    // 1. sequential two-pass (the paper's Opt-4 rung)
    let t0 = std::time::Instant::now();
    let blurred = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd)?;
    println!("sequential two-pass SIMD: {:.2} ms", t0.elapsed().as_secs_f64() * 1e3);

    // 2. the same under an OpenMP-style execution model
    let model = OpenMpModel::new(phi_conv::config::default_threads());
    let t0 = std::time::Instant::now();
    let parallel =
        convolve_parallel(&model, &img, &k, Algorithm::TwoPass, Variant::Simd, Layout::PerPlane)?;
    println!(
        "parallel  two-pass SIMD: {:.2} ms ({} workers) — identical pixels: {}",
        t0.elapsed().as_secs_f64() * 1e3,
        model.workers(),
        parallel == blurred
    );

    // 3. the AOT Pallas artifact through PJRT (Python never runs here)
    match EnginePool::open(default_artifacts_dir()) {
        Ok(pool) => {
            let engine = pool.engine(&format!("twopass_p3_{size}"))?;
            println!("PJRT: compiled {} in {:.0} ms", engine.name, engine.compile_time_ms);
            let t0 = std::time::Instant::now();
            let out = engine.run1(&[&img.data, &k])?;
            let max_diff = out
                .iter()
                .zip(&blurred.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            println!(
                "PJRT two-pass: {:.2} ms — max diff vs native {max_diff:.2e}",
                t0.elapsed().as_secs_f64() * 1e3
            );
            assert!(max_diff < 1e-4, "PJRT and native must agree");
        }
        Err(e) => println!("PJRT path skipped ({e}); run `make artifacts`"),
    }

    // write before/after for eyeballing
    let dir = std::env::temp_dir();
    write_pgm(dir.join("phi_conv_input.pgm"), &img, 0)?;
    write_pgm(dir.join("phi_conv_blurred.pgm"), &blurred, 0)?;
    println!("wrote {}/phi_conv_{{input,blurred}}.pgm", dir.display());
    Ok(())
}
