//! END-TO-END DRIVER (EXPERIMENTS.md §End-to-end): the full system on a
//! real serving workload, proving all layers compose —
//!
//!   L1/L2  Pallas kernels lowered by `make artifacts` into HLO text
//!   RT     loaded + compiled by the PJRT runtime (actor thread)
//!   L3     coordinator routes a mixed batch of requests across the
//!          three native execution models *and* the PJRT backend,
//!          with the paper-adaptive policy for unrouted requests
//!
//! Reports throughput and latency percentiles per backend, and verifies
//! every response against the sequential oracle.
//!
//! Run: `cargo run --offline --release --example serve -- [--requests 48]`

use phi_conv::{ensure, Context, ErrorKind, Result};

use phi_conv::config::{standard_cli, RunConfig};
use phi_conv::conv::{convolve_image, Algorithm, Variant};
use phi_conv::coordinator::{Backend, ConvRequest, Coordinator, RoutePolicy};
use phi_conv::image::synth_image;
use phi_conv::metrics::SampleSet;
use phi_conv::plan::KernelSpec;
use phi_conv::util::prng::Prng;

fn main() -> Result<()> {
    let cli = standard_cli("serve", "end-to-end serving driver")
        .opt("requests", "48", "number of requests")
        .opt("executors", "2", "executor threads")
        .parse(std::env::args().skip(1))?;
    let cfg = RunConfig::resolve(&cli)?;
    let requests: usize = cli.usize_of("requests")?;
    let executors: usize = cli.usize_of("executors")?;

    let coord = Coordinator::new(&cfg, RoutePolicy::paper_default(), executors, true)
        .context("artifacts missing? run `make artifacts`")?;
    println!(
        "coordinator: {executors} executors, paper-adaptive routing, PJRT={}",
        coord.has_pjrt()
    );
    let warm = coord.warm_pjrt(cfg.planes, &cfg.sizes)?;
    for (name, ms) in &warm {
        println!("  warmed {name} ({ms:.0} ms compile)");
    }

    // mixed workload: sizes from the artifact set, four backend choices —
    // policy-routed, explicitly-pinned native/PJRT, and every fifth
    // request carrying its own (wider) kernel spec through the plan layer
    let k = phi_conv::image::gaussian_kernel(cfg.kernel_width, cfg.sigma);
    let wide_spec = KernelSpec::new(7, 1.5);
    let wide_taps = phi_conv::image::gaussian_kernel(wide_spec.width, wide_spec.sigma);
    let mut rng = Prng::new(cfg.seed);
    let t0 = std::time::Instant::now();
    let mut jobs = Vec::new();
    for i in 0..requests {
        let size = *rng.pick(&cfg.sizes);
        let img = synth_image(cfg.planes, size, size, cfg.pattern, cfg.seed + i as u64);
        let mut req = ConvRequest::new(i as u64, img.clone());
        req = match i % 4 {
            0 => req, // policy decides
            1 => req.with_backend(Backend::Pjrt),
            2 => req.with_backend(Backend::NativeOpenCl),
            _ => req.with_backend(Backend::NativeGprm),
        };
        let custom_kernel = i % 5 == 0;
        if custom_kernel {
            req = req.with_kernel(wide_spec);
        }
        jobs.push((img, custom_kernel, coord.submit(req)?));
    }

    let mut latency = SampleSet::new();
    let mut verified = 0usize;
    for (i, (input, custom_kernel, rx)) in jobs.into_iter().enumerate() {
        let resp = rx.recv().context("coordinator dropped")??;
        latency.push(resp.latency_ms());
        // verify every response against the sequential oracle (with the
        // kernel the request actually carried)
        let taps = if custom_kernel { &wide_taps } else { &k };
        let want = convolve_image(input, taps, Algorithm::TwoPass, Variant::Simd)?;
        let max_diff = resp
            .image
            .data
            .iter()
            .zip(&want.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        // 3RxC-routed responses differ in the 2h seam columns by design
        let tol = if resp.layout == phi_conv::models::Layout::Agglomerated { f32::MAX } else { 1e-4 };
        ensure!(max_diff < tol, "request {i}: max diff {max_diff}");
        if tol < f32::MAX {
            verified += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    let stats = coord.stats();
    println!("\n== end-to-end serving report ==");
    println!(
        "served {} requests in {wall:.2}s → {:.1} req/s ({verified} oracle-verified)",
        stats.served,
        stats.served as f64 / wall
    );
    println!(
        "latency  p50 {:.2} ms   p95 {:.2} ms   p99 {:.2} ms   max {:.2} ms",
        latency.percentile(50.0),
        latency.percentile(95.0),
        latency.percentile(99.0),
        latency.max()
    );
    println!("queue    p50 {:.2} ms", stats.queue_ms.percentile(50.0));
    for (backend, set) in &stats.service_ms {
        println!(
            "  {backend:8} n={:3}  service p50 {:.2} ms  p95 {:.2} ms",
            set.len(),
            set.percentile(50.0),
            set.percentile(95.0)
        );
    }
    if stats.pjrt_fallbacks > 0 {
        println!("  ({} PJRT fallbacks)", stats.pjrt_fallbacks);
    }

    // burst-shedding demo: a deliberately tiny queue in front of one
    // busy executor. try_submit either admits or refuses with a
    // structured QueueFull error — the coordinator never panics and
    // never grows memory without bound under a traffic spike.
    println!("\n== burst shedding (queue capacity 4, 1 executor) ==");
    // deadline_ms zeroed: the demo asserts on QueueFull shedding, and a
    // user-supplied --deadline-ms would otherwise turn refusals into
    // DeadlineExceeded and expire admitted jobs mid-drain
    let burst_cfg = RunConfig { queue_capacity: 4, deadline_ms: 0, ..cfg.clone() };
    let small =
        Coordinator::new(&burst_cfg, RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false)?;
    let burst = 64usize;
    // requests pre-built so the burst hits the queue back-to-back
    let burst_reqs: Vec<_> = (0..burst)
        .map(|i| {
            let img = synth_image(cfg.planes, 128, 128, cfg.pattern, cfg.seed + 9000 + i as u64);
            ConvRequest::new(9000 + i as u64, img)
        })
        .collect();
    let mut admitted = Vec::new();
    let mut shed = 0usize;
    for req in burst_reqs {
        match small.try_submit(req) {
            Ok(rx) => admitted.push(rx),
            Err(e) if e.kind() == ErrorKind::QueueFull => shed += 1,
            Err(e) => return Err(e),
        }
    }
    let mut completed = 0usize;
    for rx in &admitted {
        if rx.recv().context("burst coordinator dropped")?.is_ok() {
            completed += 1;
        }
    }
    let bst = small.stats();
    println!(
        "burst of {burst}: admitted {} (all {completed} completed), shed {shed} with QueueFull",
        admitted.len()
    );
    println!(
        "queue counters: depth peak {} of 4, shed {}, expired {}",
        bst.depth_peak, bst.shed, bst.expired
    );
    ensure!(shed > 0, "a {burst}-burst into a capacity-4 queue must shed");
    ensure!(completed == admitted.len(), "every admitted request must complete");
    ensure!(bst.shed as usize == shed, "stats must account each shed request");

    println!("end-to-end driver OK");
    Ok(())
}
