//! Stereo-matching front end — the workload the paper's kernels came
//! from ("the image convolution algorithms are taken from the real code
//! used in a stereo matching algorithm. Image convolution and scaling
//! take up most of the cycles").
//!
//! Pipeline:
//!   1. synthesise a stereo pair (right = left shifted by a known
//!      disparity, plus noise);
//!   2. Gaussian-pyramid both images — through the AOT PJRT `pyramid`
//!      artifact when available, native two-pass otherwise (conv +
//!      2× decimation, the paper's hot loop);
//!   3. block-match at the coarsest level to recover the disparity.
//!
//! The recovered disparity matching the planted one is the end-to-end
//! correctness signal. Run:
//! `cargo run --offline --release --example stereo_pipeline`

use phi_conv::Result;

use phi_conv::conv::{convolve_image, Algorithm, Variant};
use phi_conv::image::{gaussian_kernel, synth_image, Pattern, PlanarImage};
use phi_conv::runtime::{manifest::default_artifacts_dir, EnginePool};

const SIZE: usize = 288; // pyramid artifact exists at the top size; native path used here
const LEVELS: usize = 3;
const TRUE_DISPARITY: usize = 12;

fn main() -> Result<()> {
    // --- 1. synthetic stereo pair ---------------------------------------
    let left = synth_image(3, SIZE, SIZE, Pattern::Disc, 3);
    let mut right = PlanarImage::zeros(3, SIZE, SIZE);
    for p in 0..3 {
        for i in 0..SIZE {
            for j in 0..SIZE {
                let src_j = (j + TRUE_DISPARITY).min(SIZE - 1);
                right.set(p, i, j, left.get(p, i, src_j));
            }
        }
    }
    println!("stereo pair: {SIZE}x{SIZE}, planted disparity {TRUE_DISPARITY}px");

    // --- 2. Gaussian pyramids --------------------------------------------
    let k = gaussian_kernel(5, 1.0);
    let lp = pyramid(&left, &k)?;
    let rp = pyramid(&right, &k)?;
    for (i, lvl) in lp.iter().enumerate() {
        println!("  level {i}: {}x{}", lvl.rows, lvl.cols);
    }

    // --- 3. coarse block matching ----------------------------------------
    // at level 2 the disparity shrinks by 4×
    let coarse = &lp[LEVELS - 1];
    let coarse_r = &rp[LEVELS - 1];
    let est = match_disparity(coarse, coarse_r, 8);
    let est_full = est * (1 << (LEVELS - 1));
    println!("estimated disparity: {est} at level {} = {est_full}px full-res", LEVELS - 1);
    let err = (est_full as i64 - TRUE_DISPARITY as i64).abs();
    println!("error vs planted: {err}px");
    assert!(err <= 4, "coarse disparity should land within one coarse pixel");
    println!("stereo front-end OK");
    Ok(())
}

/// Blur + decimate pyramid. Uses the PJRT pyramid artifact when this
/// size has one; falls back to the native two-pass engines.
fn pyramid(img: &PlanarImage, k: &[f32]) -> Result<Vec<PlanarImage>> {
    if let Ok(pool) = EnginePool::open(default_artifacts_dir()) {
        let name = format!("pyramid_{}", img.rows);
        if pool.manifest().get(&name).is_ok() {
            let engine = pool.engine(&name)?;
            let outs = engine.run(&[&img.data, k])?;
            println!("  (pyramid via PJRT artifact {name})");
            let mut levels = Vec::new();
            let mut n = img.rows;
            for o in outs {
                levels.push(PlanarImage::from_vec(img.planes, n, n, o)?);
                n /= 2;
            }
            return Ok(levels);
        }
    }
    // native fallback: conv + 2× decimation per level
    let mut levels = vec![img.clone()];
    for _ in 1..LEVELS {
        let cur = levels.last().unwrap();
        let blurred = convolve_image(cur.clone(), k, Algorithm::TwoPass, Variant::Simd)?;
        let (r2, c2) = (cur.rows / 2, cur.cols / 2);
        let mut next = PlanarImage::zeros(cur.planes, r2, c2);
        for p in 0..cur.planes {
            for i in 0..r2 {
                for j in 0..c2 {
                    next.set(p, i, j, blurred.get(p, 2 * i, 2 * j));
                }
            }
        }
        levels.push(next);
    }
    Ok(levels)
}

/// 1-D SAD block matching over plane 0: mean best horizontal shift.
fn match_disparity(left: &PlanarImage, right: &PlanarImage, max_d: usize) -> usize {
    let (rows, cols) = (left.rows, left.cols);
    let block = 8;
    let mut votes = vec![0usize; max_d + 1];
    let mut i = block;
    while i + block < rows {
        let mut j = block;
        while j + block + max_d < cols {
            let mut best = (f32::MAX, 0usize);
            for d in 0..=max_d {
                let mut sad = 0f32;
                for bi in 0..block {
                    for bj in 0..block {
                        let l = left.get(0, i + bi, j + bj + d);
                        let r = right.get(0, i + bi, j + bj);
                        sad += (l - r).abs();
                    }
                }
                if sad < best.0 {
                    best = (sad, d);
                }
            }
            votes[best.1] += 1;
            j += block;
        }
        i += block;
    }
    votes.iter().enumerate().max_by_key(|(_, &v)| v).map(|(d, _)| d).unwrap_or(0)
}
