// §Perf probe (kept as a repeatable tool): hot-path timings per layer
use phi_conv::conv::{Algorithm, Variant};
use phi_conv::image::{gaussian_kernel, synth_image, Pattern};
use phi_conv::metrics::time_reps;
use phi_conv::plan::{ConvPlan, ScratchArena};
use phi_conv::runtime::{manifest::default_artifacts_dir, EnginePool};
fn main() {
    let k = gaussian_kernel(5, 1.0);
    let img = synth_image(3, 576, 576, Pattern::Noise, 1);
    for (name, alg, v) in [
        ("twopass simd", Algorithm::TwoPass, Variant::Simd),
        ("twopass scalar", Algorithm::TwoPass, Variant::Scalar),
        ("singlepass simd", Algorithm::SinglePassNoCopy, Variant::Simd),
        ("singlepass+cb simd", Algorithm::SinglePassCopyBack, Variant::Simd),
        ("naive", Algorithm::SinglePassCopyBack, Variant::Naive),
    ] {
        let plan = ConvPlan::builder()
            .algorithm(alg)
            .variant(v)
            .shape(3, 576, 576)
            .build()
            .unwrap();
        let mut arena = ScratchArena::new();
        let s = time_reps(|| plan.execute_discard(None, &img, &mut arena).unwrap(), 3, 12);
        let mpx = (3 * 576 * 576) as f64 / s.median() / 1e3;
        println!("native {name:22} {:7.3} ms ({mpx:4.0} Mpx/s)", s.median());
    }
    if let Ok(pool) = EnginePool::open(default_artifacts_dir()) {
        for (name, n) in [("twopass_p3_576", 576usize), ("singlepass_p3_576", 576)] {
            let img = synth_image(3, n, n, Pattern::Noise, 1);
            let e = pool.engine(name).unwrap();
            let s = time_reps(|| { e.run(&[&img.data, &k]).unwrap(); }, 2, 6);
            println!("PJRT   {name:22} {:7.3} ms ({:4.0} Mpx/s)", s.median(), (3*n*n) as f64/s.median()/1e3);
        }
    }
}
