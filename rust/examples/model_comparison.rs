//! Model comparison: the paper's core experiment as a library call —
//! run the two-pass convolution under all three execution models and
//! print per-model timing plus the empty-task overhead split (the
//! paper's Table 2 methodology), then the simulated Xeon Phi rendition
//! next to it.
//!
//! Run: `cargo run --offline --release --example model_comparison -- [--sizes 288,576]`

use phi_conv::Result;

use phi_conv::config::{standard_cli, RunConfig};
use phi_conv::conv::{Algorithm, Variant};
use phi_conv::harness;
use phi_conv::image::synth_image;
use phi_conv::metrics::{time_reps, Table};
use phi_conv::models::{
    convolve_parallel, ExecutionModel, GprmModel, Layout, OpenClModel, OpenMpModel,
};

fn main() -> Result<()> {
    let cli = standard_cli("model_comparison", "three execution models head-to-head")
        .parse(std::env::args().skip(1))?;
    let cfg = RunConfig::resolve(&cli)?;
    let k = phi_conv::image::gaussian_kernel(cfg.kernel_width, cfg.sigma);

    let openmp = OpenMpModel::new(cfg.threads);
    let opencl = OpenClModel::new(cfg.threads, 16);
    let gprm = GprmModel::new(cfg.threads, cfg.cutoff);
    let models: [&dyn ExecutionModel; 3] = [&openmp, &opencl, &gprm];

    let mut t = Table::new(
        format!("measured on host ({} threads, cutoff {})", cfg.threads, cfg.cutoff),
        &["Image Size", "Model", "two-pass SIMD ms", "empty-dispatch ms", "compute ms"],
    );
    for &size in &cfg.sizes {
        let img = synth_image(cfg.planes, size, size, cfg.pattern, cfg.seed);
        for m in models {
            let total = time_reps(
                || {
                    convolve_parallel(m, &img, &k, Algorithm::TwoPass, Variant::Simd, Layout::PerPlane)
                        .unwrap();
                },
                cfg.warmup,
                cfg.reps,
            )
            .median();
            // paper Table 2 methodology: measure empty dispatches of the
            // same shape, subtract
            let dispatches = 2 * cfg.planes;
            let overhead = m.overhead_probe(size, 10).median() * dispatches as f64;
            t.row(vec![
                format!("{size}x{size}"),
                m.name().to_string(),
                format!("{total:.2}"),
                format!("{overhead:.3}"),
                format!("{:.2}", total - overhead),
            ]);
        }
    }
    println!("{}", t.to_text());

    println!("…and the simulated Xeon Phi rendition (paper values alongside):");
    for t in harness::simulated("table2")? {
        println!("{}", t.to_text());
    }

    // the paper's cutoff lever: GPRM overhead scales with task count
    let mut t = Table::new("GPRM cutoff ablation (measured empty dispatches)", &["cutoff", "dispatch ms"]);
    for cutoff in [1usize, 10, 100, 480, 1000] {
        let m = gprm.with_cutoff(cutoff);
        t.row(vec![cutoff.to_string(), format!("{:.4}", m.overhead_probe(1 << 16, 10).median())]);
    }
    println!("{}", t.to_text());
    Ok(())
}
