#!/usr/bin/env bash
# Tier-1 verify (ROADMAP.md) plus bench compilation, run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q"
cargo test -q

echo "== cargo build --benches"
cargo build --benches

echo "verify: OK"
