#!/usr/bin/env bash
# Tier-1 verify (ROADMAP.md) plus bench compilation and lint gates,
# run from anywhere. Tier-1 commands run first so a functional failure
# is always the first error; clippy gates next; fmt gates last (so a
# formatting-only failure proves everything functional already passed).
# The fmt gate is enforcing (PR 3 established the baseline); set
# PHI_VERIFY_SKIP_FMT=1 only for local runs without rustfmt installed.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q"
cargo test -q

echo "== cargo test -q --test queue_stress (coordinator under load)"
# tier-1 by policy: shedding, deadlines and shutdown-under-load must
# never panic or hang a client (already part of `cargo test`; re-run
# standalone so a load-path regression is named in the output)
cargo test -q --test queue_stress

echo "== cargo test -q --test tiling_suite (dispatch cover-exactness + tiled equivalence)"
# tier-1 by policy: a scheduling bug that loses or double-executes a
# tile corrupts pixels silently; re-run standalone so it is named
cargo test -q --test tiling_suite

echo "== cargo test -q --test fused_suite (fused ≡ unfused differential + ring leases)"
# tier-1 by policy: a fused-pipeline bug corrupts pixels silently and a
# ring-lease bug races workers; re-run standalone so it is named
cargo test -q --test fused_suite

echo "== cargo test -q --test costmodel_suite (regression core + predictive admission)"
# tier-1 by policy: a cost-model bug silently mis-plans every unseen
# shape and a persistence bug corrupts tuning artifacts; re-run
# standalone so it is named
cargo test -q --test costmodel_suite

echo "== cargo test -q --test graph_suite (streamed chains ≡ materialized + graph serving)"
# tier-1 by policy: a cascade bug corrupts every chained pixel silently
# and a demotion bug reads half-written planes; re-run standalone so a
# graph regression is named in the output
cargo test -q --test graph_suite

echo "== cargo test -q --test loadgen_suite (load harness end to end)"
# tier-1 by policy: an accounting bug in the load harness (a request
# that resolves to nothing, or an unstructured refusal) silently
# invalidates every SLO number the repo quotes; re-run standalone so a
# harness regression is named in the output
cargo test -q --test loadgen_suite

echo "== cargo test -q --test crossover_suite (cross-class differentials)"
# tier-1 by policy: the direct-2D and FFT convolvers are whole new
# execution paths — a divergence from the separable engines corrupts
# pixels silently; re-run standalone so a class regression is named
cargo test -q --test crossover_suite

echo "== phi-conv load --scale 1 (traffic mix smoke, tiny plan, no artifact)"
# end-to-end CLI smoke: generate a deterministic mix, drive the real
# coordinator in both loop modes, print the SLO table; --out none skips
# the artifact write (CI's bench smoke owns BENCH_load.json)
cargo run --release --bin phi-conv -- load --scale 1 --per-scale 12 --rate 2000 --out none

echo "== phi-conv graph --check (2-stage streamed vs materialized, bitwise)"
# end-to-end CLI smoke on a tiny image: generic widths share every
# accumulation expression, so --check demands bitwise equality
cargo run --release --bin phi-conv -- graph --stages blur:3,blur:7 --sizes 48 --reps 2 --check

echo "== phi-conv crossover --check (direct2d vs fft vs two-pass differentials)"
# end-to-end CLI smoke on a tiny image: every swept width is
# differential-checked (fft vs direct <= 1e-4, direct vs separable
# two-pass <= 1e-6) before timing; --out none skips the artifact write
# (CI's bench smoke owns BENCH_crossover.json)
cargo run --release --bin phi-conv -- crossover --check --sizes 64 --reps 1 --out none

echo "== cargo build --benches"
cargo build --benches

echo "== cargo clippy --all-targets -- -D warnings"
# scoped to the phi-conv package: vendor/xla is a frozen API stub whose
# warnings are not actionable here (crate-wide allowlist: src/lib.rs);
# --all-targets lints the tests, benches and examples too
cargo clippy -p phi-conv --all-targets -- -D warnings

if [ "${PHI_VERIFY_SKIP_FMT:-0}" != "1" ]; then
    echo "== cargo fmt --check"
    cargo fmt -p phi-conv --check
fi

echo "verify: OK"
